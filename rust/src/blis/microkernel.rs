//! Native f64 micro-kernel: the innermost compute of the BLIS GEMM
//! (Fig. 1, "Micro-kernel"): `C(mr×nr) += A_slice(mr×kc) · Br(kc×nr)`,
//! implemented as a loop of rank-1 updates over packed micro-panels —
//! the same structure the paper's hand-tuned NEON kernel has (mr=nr=4).
//!
//! Operand layout (produced by [`crate::blis::packing`]):
//! * `a`: column-major `mr×kc` slice — element (i, l) at `a[l*mr + i]`;
//! * `b`: row-major `kc×nr` micro-panel — element (l, j) at `b[l*nr + j]`;
//! * `c`: an `mr×nr` window into the output, row stride `ldc` (row-major
//!   storage of C throughout this crate).
//!
//! The generic path handles any (mr, nr); the `4×4` fast path keeps the
//! accumulators in 16 named locals so rustc maps them to registers —
//! the hot path of the native executor (DESIGN.md §10).

/// Generic micro-kernel for arbitrary register blocking. `m_eff`/`n_eff`
/// handle edge tiles (≤ mr/nr): only the first `m_eff` rows and `n_eff`
/// columns of the register block are written back.
#[allow(clippy::too_many_arguments)]
pub fn micro_kernel_generic(
    mr: usize,
    nr: usize,
    kc: usize,
    a: &[f64],
    b: &[f64],
    c: &mut [f64],
    ldc: usize,
    m_eff: usize,
    n_eff: usize,
) {
    debug_assert!(a.len() >= mr * kc);
    debug_assert!(b.len() >= kc * nr);
    debug_assert!(m_eff <= mr && n_eff <= nr);
    // Accumulate the full register block, write back the live window —
    // exactly what a padded edge micro-kernel does.
    let mut acc = vec![0.0f64; mr * nr];
    for l in 0..kc {
        let a_col = &a[l * mr..l * mr + mr];
        let b_row = &b[l * nr..l * nr + nr];
        for i in 0..mr {
            let ai = a_col[i];
            let row = &mut acc[i * nr..i * nr + nr];
            for j in 0..nr {
                row[j] += ai * b_row[j];
            }
        }
    }
    for i in 0..m_eff {
        for j in 0..n_eff {
            c[i * ldc + j] += acc[i * nr + j];
        }
    }
}

/// Specialized 4×4 micro-kernel (the paper's register blocking for both
/// core types, §3.3). Fully-interior tiles only (`m_eff = n_eff = 4`).
#[inline]
pub fn micro_kernel_4x4(kc: usize, a: &[f64], b: &[f64], c: &mut [f64], ldc: usize) {
    debug_assert!(a.len() >= 4 * kc);
    debug_assert!(b.len() >= 4 * kc);
    debug_assert!(c.len() >= 3 * ldc + 4);

    let (mut c00, mut c01, mut c02, mut c03) = (0.0f64, 0.0, 0.0, 0.0);
    let (mut c10, mut c11, mut c12, mut c13) = (0.0f64, 0.0, 0.0, 0.0);
    let (mut c20, mut c21, mut c22, mut c23) = (0.0f64, 0.0, 0.0, 0.0);
    let (mut c30, mut c31, mut c32, mut c33) = (0.0f64, 0.0, 0.0, 0.0);

    // SAFETY: bounds asserted above; the loop indexes strictly below
    // 4*kc for both panels.
    unsafe {
        let pa = a.as_ptr();
        let pb = b.as_ptr();
        for l in 0..kc {
            let a0 = *pa.add(4 * l);
            let a1 = *pa.add(4 * l + 1);
            let a2 = *pa.add(4 * l + 2);
            let a3 = *pa.add(4 * l + 3);
            let b0 = *pb.add(4 * l);
            let b1 = *pb.add(4 * l + 1);
            let b2 = *pb.add(4 * l + 2);
            let b3 = *pb.add(4 * l + 3);

            c00 += a0 * b0;
            c01 += a0 * b1;
            c02 += a0 * b2;
            c03 += a0 * b3;
            c10 += a1 * b0;
            c11 += a1 * b1;
            c12 += a1 * b2;
            c13 += a1 * b3;
            c20 += a2 * b0;
            c21 += a2 * b1;
            c22 += a2 * b2;
            c23 += a2 * b3;
            c30 += a3 * b0;
            c31 += a3 * b1;
            c32 += a3 * b2;
            c33 += a3 * b3;
        }
    }

    c[0] += c00;
    c[1] += c01;
    c[2] += c02;
    c[3] += c03;
    c[ldc] += c10;
    c[ldc + 1] += c11;
    c[ldc + 2] += c12;
    c[ldc + 3] += c13;
    c[2 * ldc] += c20;
    c[2 * ldc + 1] += c21;
    c[2 * ldc + 2] += c22;
    c[2 * ldc + 3] += c23;
    c[3 * ldc] += c30;
    c[3 * ldc + 1] += c31;
    c[3 * ldc + 2] += c32;
    c[3 * ldc + 3] += c33;
}

/// Specialized 8×4 micro-kernel — the §6 future-work per-core-type
/// register blocking for the big cores (each `Br` row is loaded once
/// per *eight* C rows instead of four). Interior tiles only.
#[inline]
pub fn micro_kernel_8x4(kc: usize, a: &[f64], b: &[f64], c: &mut [f64], ldc: usize) {
    debug_assert!(a.len() >= 8 * kc);
    debug_assert!(b.len() >= 4 * kc);
    debug_assert!(c.len() >= 7 * ldc + 4);

    let mut acc = [[0.0f64; 4]; 8];
    // SAFETY: bounds asserted above.
    unsafe {
        let pa = a.as_ptr();
        let pb = b.as_ptr();
        for l in 0..kc {
            let b0 = *pb.add(4 * l);
            let b1 = *pb.add(4 * l + 1);
            let b2 = *pb.add(4 * l + 2);
            let b3 = *pb.add(4 * l + 3);
            for (i, row) in acc.iter_mut().enumerate() {
                let ai = *pa.add(8 * l + i);
                row[0] += ai * b0;
                row[1] += ai * b1;
                row[2] += ai * b2;
                row[3] += ai * b3;
            }
        }
    }
    for (i, row) in acc.iter().enumerate() {
        for (j, &v) in row.iter().enumerate() {
            c[i * ldc + j] += v;
        }
    }
}

/// Dispatch: use the 4×4 fast path when the tile is interior and the
/// blocking is the paper's 4×4; otherwise fall back to the generic path.
#[allow(clippy::too_many_arguments)]
pub fn micro_kernel(
    mr: usize,
    nr: usize,
    kc: usize,
    a: &[f64],
    b: &[f64],
    c: &mut [f64],
    ldc: usize,
    m_eff: usize,
    n_eff: usize,
) {
    if mr == 4 && nr == 4 && m_eff == 4 && n_eff == 4 {
        micro_kernel_4x4(kc, a, b, c, ldc);
    } else if mr == 8 && nr == 4 && m_eff == 8 && n_eff == 4 {
        micro_kernel_8x4(kc, a, b, c, ldc);
    } else {
        micro_kernel_generic(mr, nr, kc, a, b, c, ldc, m_eff, n_eff);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    /// Reference: dense mr×nr += (mr×kc)·(kc×nr) on the packed layouts.
    fn reference(
        mr: usize,
        nr: usize,
        kc: usize,
        a: &[f64],
        b: &[f64],
        c: &mut [f64],
        ldc: usize,
        m_eff: usize,
        n_eff: usize,
    ) {
        for i in 0..m_eff {
            for j in 0..n_eff {
                let mut s = 0.0;
                for l in 0..kc {
                    s += a[l * mr + i] * b[l * nr + j];
                }
                c[i * ldc + j] += s;
            }
        }
    }

    fn random_case(rng: &mut Rng, mr: usize, nr: usize, kc: usize) -> (Vec<f64>, Vec<f64>) {
        (rng.fill_matrix(mr * kc), rng.fill_matrix(kc * nr))
    }

    #[test]
    fn fast_path_matches_reference() {
        let mut rng = Rng::new(100);
        for kc in [1usize, 2, 7, 64, 352, 952] {
            let (a, b) = random_case(&mut rng, 4, 4, kc);
            let mut c_fast = rng.fill_matrix(4 * 8);
            let mut c_ref = c_fast.clone();
            micro_kernel_4x4(kc, &a, &b, &mut c_fast, 8);
            reference(4, 4, kc, &a, &b, &mut c_ref, 8, 4, 4);
            for (x, y) in c_fast.iter().zip(&c_ref) {
                assert!((x - y).abs() < 1e-10 * kc as f64, "kc={kc}");
            }
        }
    }

    #[test]
    fn generic_path_various_blockings() {
        let mut rng = Rng::new(101);
        for &(mr, nr) in &[(2usize, 2usize), (4, 4), (6, 8), (8, 4), (1, 1)] {
            let kc = 37;
            let (a, b) = random_case(&mut rng, mr, nr, kc);
            let ldc = nr + 3;
            let mut c = rng.fill_matrix(mr * ldc);
            let mut c_ref = c.clone();
            micro_kernel_generic(mr, nr, kc, &a, &b, &mut c, ldc, mr, nr);
            reference(mr, nr, kc, &a, &b, &mut c_ref, ldc, mr, nr);
            for (x, y) in c.iter().zip(&c_ref) {
                assert!((x - y).abs() < 1e-11, "mr={mr} nr={nr}");
            }
        }
    }

    #[test]
    fn fast_8x4_matches_reference() {
        let mut rng = Rng::new(105);
        for kc in [1usize, 33, 352] {
            let (a, b) = random_case(&mut rng, 8, 4, kc);
            let ldc = 6;
            let mut c_fast = rng.fill_matrix(8 * ldc);
            let mut c_ref = c_fast.clone();
            micro_kernel_8x4(kc, &a, &b, &mut c_fast, ldc);
            reference(8, 4, kc, &a, &b, &mut c_ref, ldc, 8, 4);
            for (x, y) in c_fast.iter().zip(&c_ref) {
                assert!((x - y).abs() < 1e-10 * kc as f64, "kc={kc}");
            }
        }
    }

    #[test]
    fn dispatch_hits_8x4_path() {
        let mut rng = Rng::new(106);
        let (a, b) = random_case(&mut rng, 8, 4, 17);
        let mut c_d = vec![0.0; 32];
        let mut c_g = vec![0.0; 32];
        micro_kernel(8, 4, 17, &a, &b, &mut c_d, 4, 8, 4);
        micro_kernel_generic(8, 4, 17, &a, &b, &mut c_g, 4, 8, 4);
        for (x, y) in c_d.iter().zip(&c_g) {
            assert!((x - y).abs() < 1e-11);
        }
    }

    #[test]
    fn edge_tiles_do_not_write_outside_live_window() {
        let mut rng = Rng::new(102);
        let (mr, nr, kc) = (4, 4, 20);
        let (a, b) = random_case(&mut rng, mr, nr, kc);
        let ldc = 6;
        let mut c = vec![7.0; mr * ldc];
        let before = c.clone();
        micro_kernel(mr, nr, kc, &a, &b, &mut c, ldc, 2, 3);
        for i in 0..mr {
            for j in 0..ldc {
                let touched = i < 2 && j < 3;
                if !touched {
                    assert_eq!(c[i * ldc + j], before[i * ldc + j], "({i},{j}) clobbered");
                }
            }
        }
    }

    #[test]
    fn accumulates_into_c() {
        // C += A·B run twice doubles the update.
        let mut rng = Rng::new(103);
        let (a, b) = random_case(&mut rng, 4, 4, 16);
        let mut c1 = vec![0.0; 16];
        micro_kernel_4x4(16, &a, &b, &mut c1, 4);
        let mut c2 = vec![0.0; 16];
        micro_kernel_4x4(16, &a, &b, &mut c2, 4);
        micro_kernel_4x4(16, &a, &b, &mut c2, 4);
        for (x, y) in c1.iter().zip(&c2) {
            assert!((2.0 * x - y).abs() < 1e-11);
        }
    }

    #[test]
    fn kc_zero_is_identity() {
        let a: Vec<f64> = vec![];
        let b: Vec<f64> = vec![];
        let mut c = vec![1.0; 16];
        micro_kernel(4, 4, 0, &a, &b, &mut c, 4, 4, 4);
        assert!(c.iter().all(|&x| x == 1.0));
    }

    #[test]
    fn dispatch_uses_fast_and_generic_consistently() {
        let mut rng = Rng::new(104);
        let (a, b) = random_case(&mut rng, 4, 4, 33);
        let mut c_d = vec![0.0; 16];
        let mut c_g = vec![0.0; 16];
        micro_kernel(4, 4, 33, &a, &b, &mut c_d, 4, 4, 4);
        micro_kernel_generic(4, 4, 33, &a, &b, &mut c_g, 4, 4, 4);
        for (x, y) in c_d.iter().zip(&c_g) {
            assert!((x - y).abs() < 1e-11);
        }
    }

    /// Property: random blockings, edges and strides all agree with the
    /// dense reference.
    #[test]
    fn prop_micro_kernel_matches_reference() {
        crate::util::prop::check_default(
            |r| {
                let mr = r.gen_range(1, 9);
                let nr = r.gen_range(1, 9);
                let kc = r.gen_range(1, 80);
                let m_eff = r.gen_range(1, mr + 1);
                let n_eff = r.gen_range(1, nr + 1);
                let ldc = nr + r.gen_range(0, 5);
                (mr, nr, kc, m_eff, n_eff, ldc, r.next_u64())
            },
            |&(mr, nr, kc, m_eff, n_eff, ldc, seed)| {
                let mut rng = Rng::new(seed);
                let a = rng.fill_matrix(mr * kc);
                let b = rng.fill_matrix(kc * nr);
                let mut c = rng.fill_matrix(mr * ldc);
                let mut c_ref = c.clone();
                micro_kernel(mr, nr, kc, &a, &b, &mut c, ldc, m_eff, n_eff);
                reference(mr, nr, kc, &a, &b, &mut c_ref, ldc, m_eff, n_eff);
                for (idx, (x, y)) in c.iter().zip(&c_ref).enumerate() {
                    if (x - y).abs() > 1e-10 * kc as f64 {
                        return Err(format!("mismatch at {idx}: {x} vs {y}"));
                    }
                }
                Ok(())
            },
        );
    }
}
