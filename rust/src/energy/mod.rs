//! Power/energy model and the pmlib-style virtual sampler.
//!
//! The paper instruments the ODROID-XU3 with pmlib (§3.2): four sensors
//! (A15 cluster, A7 cluster, DRAM, GPU) sampled every 250 ms, and reports
//! whole-SoC GFLOPS/W — including the power of the *idle* complementary
//! cluster (§3.4). We reproduce that accounting over the simulator's
//! virtual timelines, generalized to one sensor rail per cluster of the
//! topology:
//!
//! `P(t) = P_gpu_idle + P_dram_idle + Σ_cluster P_cluster_idle
//!        + Σ_core increment(state_core(t)) + DRAM dynamic`
//!
//! Core states: `Busy` (computing or packing), `Poll` (spin-waiting at a
//! barrier or for another cluster — the §5.2.2 energy drain of
//! unbalanced schedules), `Idle`. Per-cluster rails come from each
//! cluster's `soc::ClusterTuning`; SoC-level constants live in
//! [`crate::model::calibration`] with paper-anchored tests.

use crate::model::calibration as cal;
use crate::soc::{ClusterId, SocSpec};

/// What a core is doing during a timeline segment.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum CoreState {
    Busy,
    Poll,
    Idle,
}

/// Per-core activity totals over one run (seconds).
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct CoreActivity {
    pub busy_s: f64,
    pub poll_s: f64,
}

/// Aggregated energy/power report for one simulated run.
#[derive(Debug, Clone, PartialEq)]
pub struct EnergyReport {
    pub duration_s: f64,
    pub energy_j: f64,
    /// Sensor-style per-cluster rails, indexed by [`ClusterId`]
    /// (pmlib's A15/A7 sensors, generalized to N clusters).
    pub energy_clusters_j: Vec<f64>,
    pub energy_dram_j: f64,
    pub energy_gpu_j: f64,
    pub avg_power_w: f64,
}

impl EnergyReport {
    /// Whole-SoC energy efficiency for `flops` of useful work.
    pub fn gflops_per_watt(&self, flops: f64) -> f64 {
        assert!(self.energy_j > 0.0);
        flops / self.energy_j / 1e9
    }

    /// One cluster's sensor rail.
    pub fn cluster_rail_j(&self, c: ClusterId) -> f64 {
        self.energy_clusters_j[c.0]
    }

    /// Accumulate this report's joules into a metrics registry as
    /// monotone counters, one per sensor rail:
    /// `{prefix}_energy_j` (whole SoC), `{prefix}_energy_c{c}_j`
    /// per cluster, plus the DRAM and GPU rails. A no-op on a
    /// disabled registry.
    pub fn export_metrics(&self, reg: &mut crate::obs::MetricsRegistry, prefix: &str) {
        if !reg.enabled() {
            return;
        }
        reg.inc(&format!("{prefix}_energy_j"), self.energy_j);
        for (c, &j) in self.energy_clusters_j.iter().enumerate() {
            reg.inc(&format!("{prefix}_energy_c{c}_j"), j);
        }
        reg.inc(&format!("{prefix}_energy_dram_j"), self.energy_dram_j);
        reg.inc(&format!("{prefix}_energy_gpu_j"), self.energy_gpu_j);
    }
}

/// The power model bound to a SoC descriptor.
#[derive(Debug, Clone)]
pub struct PowerModel {
    pub soc: SocSpec,
}

impl PowerModel {
    pub fn new(soc: SocSpec) -> Self {
        PowerModel { soc }
    }

    pub fn exynos() -> Self {
        PowerModel::new(SocSpec::exynos5422())
    }

    /// Instantaneous increment a single core adds above its cluster
    /// baseline in the given state.
    pub fn core_increment_w(&self, c: ClusterId, state: CoreState) -> f64 {
        let tuning = &self.soc[c].tuning;
        match state {
            CoreState::Busy => tuning.p_core_active_w,
            CoreState::Poll => tuning.p_core_poll_w(cal::POLL_FACTOR),
            CoreState::Idle => 0.0,
        }
    }

    /// Constant baseline power of the whole SoC (every cluster's idle
    /// rail + DRAM idle + GPU idle) — drawn for the entire run.
    pub fn baseline_w(&self) -> f64 {
        self.soc
            .clusters
            .iter()
            .map(|c| c.tuning.p_cluster_idle_w)
            .sum::<f64>()
            + cal::P_DRAM_IDLE
            + cal::P_GPU_IDLE
    }

    /// Integrate energy for a run of `duration_s` given per-core
    /// activity totals (indexed by the SoC's global core ids) and total
    /// DRAM payload bytes moved.
    pub fn integrate(
        &self,
        duration_s: f64,
        activity: &[CoreActivity],
        dram_bytes: f64,
    ) -> EnergyReport {
        assert_eq!(activity.len(), self.soc.total_cores());
        assert!(duration_s >= 0.0);
        for (id, a) in activity.iter().enumerate() {
            assert!(
                a.busy_s + a.poll_s <= duration_s * (1.0 + 1e-9) + 1e-12,
                "core {id}: busy {} + poll {} exceeds duration {duration_s}",
                a.busy_s,
                a.poll_s
            );
        }

        let mut clusters: Vec<f64> = self
            .soc
            .clusters
            .iter()
            .map(|c| c.tuning.p_cluster_idle_w * duration_s)
            .collect();
        for (id, a) in activity.iter().enumerate() {
            let c = self.soc.cluster_of_core(id);
            clusters[c.0] += self.core_increment_w(c, CoreState::Busy) * a.busy_s
                + self.core_increment_w(c, CoreState::Poll) * a.poll_s;
        }
        let dram = cal::P_DRAM_IDLE * duration_s + dram_bytes * cal::DRAM_NJ_PER_BYTE * 1e-9;
        let gpu = cal::P_GPU_IDLE * duration_s;
        let energy = clusters.iter().sum::<f64>() + dram + gpu;
        EnergyReport {
            duration_s,
            energy_j: energy,
            energy_clusters_j: clusters,
            energy_dram_j: dram,
            energy_gpu_j: gpu,
            avg_power_w: if duration_s > 0.0 { energy / duration_s } else { 0.0 },
        }
    }
}

/// pmlib-style sampler: renders a run's average power as the paper's
/// 250 ms instantaneous readings would have seen it. Used by the energy
/// report example and tested for consistency with `integrate`.
#[derive(Debug, Clone)]
pub struct PmlibSampler {
    pub period_s: f64,
}

impl Default for PmlibSampler {
    fn default() -> Self {
        PmlibSampler {
            period_s: cal::PMLIB_SAMPLE_PERIOD_S,
        }
    }
}

/// One sampled power reading (whole SoC plus per-cluster rails).
#[derive(Debug, Clone, PartialEq)]
pub struct PowerSample {
    pub t_s: f64,
    pub total_w: f64,
    /// Per-cluster rail readings, indexed by [`ClusterId`].
    pub cluster_w: Vec<f64>,
}

impl PmlibSampler {
    /// Sample a run assuming piecewise-constant average behaviour: the
    /// per-core duty cycles are spread uniformly over the run (the DES
    /// timeline keeps only aggregates; for sampling granularity studies
    /// this uniform rendering matches the paper's steady-state kernels).
    pub fn sample(
        &self,
        model: &PowerModel,
        duration_s: f64,
        activity: &[CoreActivity],
    ) -> Vec<PowerSample> {
        let mut samples = Vec::new();
        if duration_s <= 0.0 {
            return samples;
        }
        let mut cluster_w: Vec<f64> = model
            .soc
            .clusters
            .iter()
            .map(|c| c.tuning.p_cluster_idle_w)
            .collect();
        for (id, a) in activity.iter().enumerate() {
            let c = model.soc.cluster_of_core(id);
            let duty_busy = (a.busy_s / duration_s).min(1.0);
            let duty_poll = (a.poll_s / duration_s).min(1.0);
            cluster_w[c.0] += model.core_increment_w(c, CoreState::Busy) * duty_busy
                + model.core_increment_w(c, CoreState::Poll) * duty_poll;
        }
        let total = cluster_w.iter().sum::<f64>() + cal::P_DRAM_IDLE + cal::P_GPU_IDLE;
        let mut t = 0.0;
        while t < duration_s {
            samples.push(PowerSample {
                t_s: t,
                total_w: total,
                cluster_w: cluster_w.clone(),
            });
            t += self.period_s;
        }
        samples
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::soc::{BIG, LITTLE};

    fn full_busy(soc: &SocSpec, ids: std::ops::Range<usize>, dur: f64) -> Vec<CoreActivity> {
        let mut v = vec![CoreActivity::default(); soc.total_cores()];
        for id in ids {
            v[id].busy_s = dur;
        }
        v
    }

    /// §3.4 energy anchors, all in one scenario table. Rates come from
    /// the calibrated perf model anchors (2.95/core A15, …).
    #[test]
    fn anchor_cluster_efficiencies() {
        let pm = PowerModel::exynos();
        let soc = pm.soc.clone();
        let dur = 1.0;
        let gf = |rate: f64, rep: &EnergyReport| rep.gflops_per_watt(rate * 1e9);

        // 1× A15 busy.
        let e1 = pm.integrate(dur, &full_busy(&soc, 0..1, dur), 0.0);
        let eff1 = gf(2.95, &e1);
        // 3× A15 busy.
        let e3 = pm.integrate(dur, &full_busy(&soc, 0..3, dur), 0.0);
        let eff3 = gf(8.54, &e3);
        // 4× A15 busy.
        let e4 = pm.integrate(dur, &full_busy(&soc, 0..4, dur), 0.0);
        let eff4 = gf(9.6, &e4);
        // 1× A7, 4× A7.
        let l1 = gf(0.58, &pm.integrate(dur, &full_busy(&soc, 4..5, dur), 0.0));
        let l4 = gf(2.31, &pm.integrate(dur, &full_busy(&soc, 4..8, dur), 0.0));

        // Best A15 efficiency at 3 cores, +20–45 % over one core (§3.4).
        assert!(eff3 > eff4 && eff3 > eff1, "{eff1} {eff3} {eff4}");
        let gain = eff3 / eff1 - 1.0;
        assert!((0.20..0.45).contains(&gain), "3-core gain {gain}");
        // Full A7 ≈ 2× single A7.
        let a7_gain = l4 / l1;
        assert!((1.7..2.6).contains(&a7_gain), "A7 gain {a7_gain}");
        // Full A7 cluster beats a single A15 core (§3.4).
        assert!(l4 > eff1, "4×A7 {l4} vs 1×A15 {eff1}");
        // Full clusters have similar efficiency (§3.4).
        let rel = (l4 - eff4).abs() / eff4;
        assert!(rel < 0.15, "full-cluster efficiencies differ {rel}");
    }

    #[test]
    fn polling_costs_energy() {
        // §5.2.2: fast threads polling while slow threads finish.
        let pm = PowerModel::exynos();
        let soc = pm.soc.clone();
        let dur = 1.0;
        let mut poll = full_busy(&soc, 4..8, dur);
        for a in poll.iter_mut().take(4) {
            a.poll_s = dur; // big cores spin the whole run
        }
        let idle = full_busy(&soc, 4..8, dur);
        let e_poll = pm.integrate(dur, &poll, 0.0).energy_j;
        let e_idle = pm.integrate(dur, &idle, 0.0).energy_j;
        assert!(e_poll > e_idle + 4.0 * 1.0, "polling must add > 1 W/core: {e_poll} vs {e_idle}");
    }

    #[test]
    fn baseline_charged_even_when_idle() {
        let pm = PowerModel::exynos();
        let soc = pm.soc.clone();
        let rep = pm.integrate(2.0, &vec![CoreActivity::default(); soc.total_cores()], 0.0);
        assert!((rep.avg_power_w - pm.baseline_w()).abs() < 1e-9);
        assert!(rep.energy_j > 1.5);
    }

    #[test]
    fn energy_additive_in_dram_bytes() {
        let pm = PowerModel::exynos();
        let soc = pm.soc.clone();
        let act = full_busy(&soc, 0..1, 1.0);
        let e0 = pm.integrate(1.0, &act, 0.0).energy_j;
        let e1 = pm.integrate(1.0, &act, 1e9).energy_j;
        assert!((e1 - e0 - 0.0625).abs() < 1e-6, "1 GB at 0.0625 nJ/B = 62.5 mJ");
    }

    #[test]
    #[should_panic(expected = "exceeds duration")]
    fn over_committed_activity_rejected() {
        let pm = PowerModel::exynos();
        let mut act = vec![CoreActivity::default(); 8];
        act[0].busy_s = 0.9;
        act[0].poll_s = 0.2;
        pm.integrate(1.0, &act, 0.0);
    }

    #[test]
    fn sampler_matches_integrated_average() {
        let pm = PowerModel::exynos();
        let soc = pm.soc.clone();
        let dur = 1.0;
        let act = full_busy(&soc, 0..4, dur);
        let rep = pm.integrate(dur, &act, 0.0);
        let samples = PmlibSampler::default().sample(&pm, dur, &act);
        assert_eq!(samples.len(), 4, "250 ms sampling of a 1 s run");
        let avg = samples.iter().map(|s| s.total_w).sum::<f64>() / samples.len() as f64;
        assert!((avg - rep.avg_power_w).abs() < 1e-6);
        assert_eq!(samples[0].cluster_w.len(), 2);
    }

    #[test]
    fn sensor_rails_sum_to_total() {
        let pm = PowerModel::exynos();
        let soc = pm.soc.clone();
        let act = full_busy(&soc, 0..8, 1.0);
        let rep = pm.integrate(1.0, &act, 1e8);
        let sum = rep.energy_clusters_j.iter().sum::<f64>() + rep.energy_dram_j + rep.energy_gpu_j;
        assert!((sum - rep.energy_j).abs() < 1e-9);
        assert!(rep.cluster_rail_j(BIG) > rep.cluster_rail_j(LITTLE));
    }

    #[test]
    fn gflops_per_watt_computation() {
        let pm = PowerModel::exynos();
        let soc = pm.soc.clone();
        let rep = pm.integrate(1.0, &vec![CoreActivity::default(); soc.total_cores()], 0.0);
        // flops / (energy · 1e9): 1e9 flops over baseline_w J.
        let expect = 1.0 / pm.baseline_w();
        assert!((rep.gflops_per_watt(1e9) - expect).abs() < 1e-9);
    }

    /// ISSUE 3: a descriptor derived at a lower operating point carries
    /// `f·V²`-scaled rails, so the same activity integrates to much
    /// less energy — the mechanism behind the DVFS Pareto frontier.
    #[test]
    fn opp_scaled_rails_integrate_lower_energy() {
        let low = SocSpec::exynos5422().at_opp(BIG, 0).at_opp(LITTLE, 0);
        let pm_low = PowerModel::new(low);
        let pm_nom = PowerModel::exynos();
        assert!(pm_low.baseline_w() < pm_nom.baseline_w());
        let act = full_busy(&pm_low.soc, 0..8, 1.0);
        let e_low = pm_low.integrate(1.0, &act, 0.0).energy_j;
        let e_nom = pm_nom.integrate(1.0, &act, 0.0).energy_j;
        assert!(e_low < 0.5 * e_nom, "f*V^2 scaling: {e_low} J vs {e_nom} J");
        // The DRAM/GPU floors do not scale — only the cluster rails.
        let rep = pm_low.integrate(1.0, &full_busy(&pm_low.soc, 0..0, 1.0), 0.0);
        assert!((rep.energy_dram_j - 0.18).abs() < 1e-12);
        assert!((rep.energy_gpu_j - 0.05).abs() < 1e-12);
    }

    #[test]
    fn tri_cluster_has_three_rails() {
        let pm = PowerModel::new(SocSpec::dynamiq_3c());
        let soc = pm.soc.clone();
        let act = full_busy(&soc, 0..soc.total_cores(), 1.0);
        let rep = pm.integrate(1.0, &act, 0.0);
        assert_eq!(rep.energy_clusters_j.len(), 3);
        let sum = rep.energy_clusters_j.iter().sum::<f64>() + rep.energy_dram_j + rep.energy_gpu_j;
        assert!((sum - rep.energy_j).abs() < 1e-9);
    }
}
