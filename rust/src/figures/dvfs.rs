//! DVFS perf/energy report (no paper counterpart — the ROADMAP
//! "per-cluster DVFS schedules" item, after the energy follow-up
//! arXiv:1507.05129 and the governor-interplay study arXiv:1509.02058).
//!
//! Three tables on the Exynos 5422 descriptor:
//! 1. the **OPP Pareto frontier** — CA-SAS pinned at every joint ladder
//!    rung: GFLOPS rises with the clock while GFLOPS/W falls with the
//!    `f·V²` law, so the performance-optimal and energy-optimal
//!    operating points differ (the acceptance criterion);
//! 2. **online retuning vs stale boot weights** under an
//!    `ondemand`-style ramp — the weight vector recomputed at every
//!    transition must beat the §5.2 ratio knob configured once at boot;
//! 3. **governor comparison** — performance/powersave/ondemand ends of
//!    the frontier.

use crate::blis::gemm::GemmShape;
use crate::dvfs::sim::{simulate_dvfs, DvfsStats, DvfsStrategy, Retune};
use crate::dvfs::{DvfsSchedule, Governor, Ondemand, Performance, Powersave};
use crate::figures::{Assertion, FigureResult};
use crate::soc::{SocSpec, BIG, LITTLE};
use crate::util::table::Table;

pub fn run(quick: bool) -> FigureResult {
    let soc = SocSpec::exynos5422();
    let r = if quick { 2048 } else { 4096 };
    let period = if quick { 0.25 } else { 0.5 };
    let shape = GemmShape::square(r);
    let strat = DvfsStrategy::Sas { cache_aware: true };

    // --- Table 1: the joint-rung OPP Pareto frontier. ---
    let rungs = soc[BIG].opps.len();
    let mut pareto = Table::new(
        &format!("OPP Pareto — CA-SAS pinned at each joint ladder rung, r = {r}"),
        &["opp", "A15 [GHz]", "A7 [GHz]", "GFLOPS", "energy [J]", "GFLOPS/W"],
    );
    let mut points: Vec<DvfsStats> = Vec::new();
    for o in 0..rungs {
        let plan = DvfsSchedule::pinned(&[o, o]);
        let st = simulate_dvfs(&soc, strat, shape, &plan, Retune::Online);
        pareto.push_row(vec![
            o.to_string(),
            format!("{:.1}", soc[BIG].opps.get(o).freq_ghz),
            format!("{:.1}", soc[LITTLE].opps.get(o).freq_ghz),
            format!("{:.2}", st.gflops),
            format!("{:.1}", st.energy_j),
            format!("{:.3}", st.gflops_per_watt),
        ]);
        points.push(st);
    }
    let argmax = |f: &dyn Fn(&DvfsStats) -> f64| -> usize {
        (0..points.len())
            .max_by(|&a, &b| f(&points[a]).total_cmp(&f(&points[b])))
            .unwrap()
    };
    let perf_opt = argmax(&|st: &DvfsStats| st.gflops);
    let energy_opt = argmax(&|st: &DvfsStats| st.gflops_per_watt);

    // --- Table 2: online retuning vs stale boot weights. ---
    let plan = Ondemand::new(period).plan(&soc, 1e3);
    let stale = simulate_dvfs(&soc, strat, shape, &plan, Retune::Boot);
    let online = simulate_dvfs(&soc, strat, shape, &plan, Retune::Online);
    let mut retune = Table::new(
        &format!("Online retuning vs stale boot weights — ondemand ramp, period {period} s, r = {r}"),
        &["weights", "makespan [s]", "GFLOPS", "energy [J]", "GFLOPS/W", "retunes", "A7 share"],
    );
    for st in [&stale, &online] {
        retune.push_row(vec![
            st.label.clone(),
            format!("{:.3}", st.time_s),
            format!("{:.2}", st.gflops),
            format!("{:.1}", st.energy_j),
            format!("{:.3}", st.gflops_per_watt),
            st.retunes.to_string(),
            format!("{:.3}", st.cluster_share[1]),
        ]);
    }

    // --- Table 3: governor comparison. ---
    let governors: Vec<(&str, DvfsSchedule)> = vec![
        ("performance", Performance.plan(&soc, 1e3)),
        ("ondemand", plan.clone()),
        ("powersave", Powersave.plan(&soc, 1e3)),
    ];
    let mut gov_table = Table::new(
        &format!("Governors — CA-SAS with online retuning, r = {r}"),
        &["governor", "makespan [s]", "GFLOPS", "energy [J]", "GFLOPS/W"],
    );
    let mut gov_stats = Vec::new();
    for (name, p) in &governors {
        let st = if *name == "ondemand" {
            online.clone()
        } else {
            simulate_dvfs(&soc, strat, shape, p, Retune::Online)
        };
        gov_table.push_row(vec![
            name.to_string(),
            format!("{:.3}", st.time_s),
            format!("{:.2}", st.gflops),
            format!("{:.1}", st.energy_j),
            format!("{:.3}", st.gflops_per_watt),
        ]);
        gov_stats.push(st);
    }
    let (perf, ond, save) = (&gov_stats[0], &gov_stats[1], &gov_stats[2]);

    let assertions = vec![
        Assertion::check(
            "performance rises monotonically along the ladder",
            points.windows(2).all(|w| w[1].gflops > w[0].gflops),
            format!(
                "GFLOPS by rung: {:?}",
                points.iter().map(|p| p.gflops).collect::<Vec<_>>()
            ),
        ),
        Assertion::check(
            "the energy-optimal OPP differs from the performance-optimal one",
            energy_opt != perf_opt,
            format!("energy-opt rung {energy_opt}, perf-opt rung {perf_opt}"),
        ),
        Assertion::check(
            "the efficiency spread is material (f*V^2 law)",
            points[energy_opt].gflops_per_watt > 1.2 * points[perf_opt].gflops_per_watt,
            format!(
                "{:.3} GFLOPS/W at rung {energy_opt} vs {:.3} at rung {perf_opt}",
                points[energy_opt].gflops_per_watt, points[perf_opt].gflops_per_watt
            ),
        ),
        Assertion::check(
            "online retuning beats stale boot weights under the ramp",
            online.gflops > stale.gflops * 1.02,
            format!("online {:.2} vs stale {:.2} GFLOPS", online.gflops, stale.gflops),
        ),
        Assertion::check(
            "retuning shifts work toward the cluster that sped up",
            online.cluster_share[1] > stale.cluster_share[1],
            format!(
                "A7 share {:.3} online vs {:.3} stale",
                online.cluster_share[1], stale.cluster_share[1]
            ),
        ),
        Assertion::check(
            "the performance governor is the fastest",
            perf.gflops > ond.gflops && ond.gflops > save.gflops,
            format!(
                "{:.2} (performance) > {:.2} (ondemand) > {:.2} (powersave)",
                perf.gflops, ond.gflops, save.gflops
            ),
        ),
        Assertion::check(
            "powersave is the most energy-efficient governor",
            save.gflops_per_watt > ond.gflops_per_watt
                && save.gflops_per_watt > perf.gflops_per_watt,
            format!(
                "{:.3} (powersave) vs {:.3} (ondemand) vs {:.3} (performance) GFLOPS/W",
                save.gflops_per_watt, ond.gflops_per_watt, perf.gflops_per_watt
            ),
        ),
        Assertion::check(
            "the ramp lands between the frontier's ends on efficiency",
            ond.gflops_per_watt > perf.gflops_per_watt,
            format!(
                "ondemand {:.3} vs performance {:.3} GFLOPS/W",
                ond.gflops_per_watt, perf.gflops_per_watt
            ),
        ),
    ];

    FigureResult {
        id: "dvfs",
        title: "DVFS operating points: perf/energy Pareto frontier and online weight retuning",
        tables: vec![pareto, retune, gov_table],
        assertions,
    }
}

#[cfg(test)]
mod tests {
    #[test]
    fn dvfs_report_passes_quick() {
        let fig = super::run(true);
        assert!(fig.passed(), "{}", fig.to_markdown());
        assert_eq!(fig.tables.len(), 3);
        assert_eq!(fig.id, "dvfs");
    }
}
