//! Live-calibration convergence report (ISSUE 9, no paper counterpart
//! — the ROADMAP "online calibration: measure while serving" item):
//! what the serving path learns about its own cluster rates, and what
//! that learning buys.
//!
//! One pinned scenario — a single exynos5422 board running *analytical*
//! CA-SAS weights over a staggered arrival stream — replayed twice:
//! once as-is (the frozen pre-calibration baseline) and once through
//! [`simulate_fleet_stream_live`], where every completed grab feeds the
//! board's [`LiveRateTable`] and the schedule re-derives its split from
//! the learned rates at each re-plan point. Four tables:
//! 1. **per-cluster rates** — analytical model vs live-learned vs the
//!    offline empirical measurement ([`RateTable::measure_with_reps`]),
//!    with per-cell sample counts;
//! 2. **weight shares** — the CA-SAS split under each source, in
//!    percentage points against the offline ground truth;
//! 3. **stream replay** — baseline vs live on the same columns as the
//!    fleet report's streaming table;
//! 4. **learning trace** — half-life, confidence gate, warmup instant,
//!    re-plan count, convergence error.
//!
//! The acceptance criteria (ISSUE 9): the board warms up mid-stream,
//! the learned shares land within 5 pp of the offline empirical shares,
//! and live CA-SAS is no slower than the analytical baseline it
//! bootstrapped from.

use crate::blis::gemm::GemmShape;
use crate::calibrate::live::LiveRateTable;
use crate::calibrate::{
    canonical_reps, current_opps, Family, RateTable, ShapeClass, WeightSource,
};
use crate::figures::fleet::{stream_row, STREAM_COLUMNS};
use crate::figures::{Assertion, FigureResult};
use crate::fleet::sim::{
    poisson_arrivals, simulate_fleet_stream_cached, simulate_fleet_stream_live,
    simulate_fleet_stream_live_traced, Arrival, LiveBoardReport, LiveStreamConfig, StreamStats,
};
use crate::fleet::{Board, Fleet};
use crate::obs::{MetricsRegistry, NullSink};
use crate::sim::RunCache;
use crate::util::rng::Rng;
use crate::util::table::Table;

/// The pinned live-calibration fleet: one exynos5422 board whose
/// schedule is CA-SAS with *analytical* weights — the cold-start
/// configuration the live table is meant to improve on, and a
/// weighted-static schedule so the mid-stream re-plan path exercises.
pub fn pinned_live_fleet() -> Fleet {
    let mut board = Board::from_preset("exynos5422").expect("preset");
    let spec = crate::calibrate::ca_sas_spec(
        &WeightSource::Analytical,
        board.model(),
        pinned_live_class(),
    );
    board.sched = spec;
    Fleet::new(vec![board])
}

/// Shape class every pinned arrival falls into: the stream's three
/// sizes (384/512/640) all have `k < kc_ref = 952` on the Exynos, so
/// the whole replay feeds one `(cluster, rung, family, Small)` cell
/// pair — warmup is a property of the stream prefix, not of shape
/// luck.
pub fn pinned_live_class() -> ShapeClass {
    ShapeClass::Small
}

/// Staggered arrivals for the live report: the fleet report's shape
/// mix at an arrival rate above the single board's capacity, so the
/// replay is service-bound and GFLOPS measures scheduling quality.
/// Deterministic (seeded [`Rng`]); `quick` halves the stream length.
pub fn pinned_live_arrivals(quick: bool) -> Vec<Arrival> {
    let shapes = [
        GemmShape::square(384),
        GemmShape::square(512),
        GemmShape::square(640),
    ];
    let count = if quick { 48 } else { 96 };
    let mut rng = Rng::new(0x11FE_CA1B);
    poisson_arrivals(&mut rng, &shapes, count, 80.0)
}

/// Everything the report, the `amp-gemm calibrate --live` subcommand
/// and the perf-trajectory rows share: both replays, what the board
/// learned, and the convergence error against the offline ground
/// truth.
pub struct LiveSummary {
    /// The frozen analytical-CA-SAS baseline replay.
    pub analytical: StreamStats,
    /// The live-calibrating replay of the same arrivals.
    pub live: StreamStats,
    /// What the (single) board learned.
    pub report: LiveBoardReport,
    /// The knobs both replays above were produced with.
    pub cfg: LiveStreamConfig,
    /// The one shape class the pinned stream exercises.
    pub class: ShapeClass,
    /// Offline empirical table on the same descriptor — the ground
    /// truth the live table should converge toward.
    pub offline: RateTable,
    /// `100 × max_c |live share − offline empirical share|`, the
    /// `live_convergence_pct` trajectory row. Shares (not raw rates)
    /// because the split is what the scheduler consumes, and shares
    /// factor out the aggregate-throughput offset between the
    /// busy-time and the isolated-cluster measurement protocols.
    pub convergence_pct: f64,
}

/// Run the pinned scenario and measure convergence — the single
/// implementation behind [`run`], the CLI and the trajectory suite.
pub fn convergence_summary(quick: bool) -> LiveSummary {
    let fleet = pinned_live_fleet();
    let arrivals = pinned_live_arrivals(quick);
    let cfg = LiveStreamConfig::default();
    let class = pinned_live_class();
    let soc = fleet.boards[0].soc();
    debug_assert!(
        arrivals.iter().all(|a| ShapeClass::for_soc(soc, a.job.equiv_gemm()) == class)
    );

    // Both replays share one cache: the pre-replan grabs of the live
    // run price against the same interned analytical-CA-SAS config the
    // baseline used.
    let mut cache = RunCache::new();
    let analytical = simulate_fleet_stream_cached(&fleet, &arrivals, &mut cache);
    let (live, mut reports) = simulate_fleet_stream_live_traced(
        &fleet,
        &arrivals,
        cfg,
        &mut cache,
        &mut NullSink,
        &mut MetricsRegistry::disabled(),
    );
    let report = reports.pop().expect("one board");

    let model = fleet.boards[0].model();
    let offline = RateTable::measure_with_reps(soc, &[], &canonical_reps());
    let live_w = WeightSource::Live { table: report.table.clone(), min_samples: cfg.min_samples }
        .weights(model, true, class)
        .normalized();
    let emp_w = WeightSource::Empirical(offline.clone())
        .weights(model, true, class)
        .normalized();
    let convergence_pct = (0..soc.num_clusters())
        .map(|c| (live_w.share(c) - emp_w.share(c)).abs())
        .fold(0.0, f64::max)
        * 100.0;

    LiveSummary { analytical, live, report, cfg, class, offline, convergence_pct }
}

pub fn run(quick: bool) -> FigureResult {
    let s = convergence_summary(quick);
    let fleet = pinned_live_fleet();
    let model = fleet.boards[0].model();
    let soc = fleet.boards[0].soc();
    let opps = current_opps(soc);

    // --- Table 1: per-cluster rates, three ways. ---
    let mut rates = Table::new(
        &format!("Per-cluster rates — analytical vs live-learned vs offline empirical, class {}",
            s.class.label()),
        &["cluster", "analytical", "live", "samples", "offline empirical", "live/offline"],
    );
    let params = model.family_params(true);
    for c in soc.cluster_ids() {
        let ana = model.cluster_rate_gflops(c, &params[c.0], soc[c].num_cores);
        let live_r = s.report.table.rate(c, opps[c.0], Family::CacheAware, s.class);
        let off_r = s
            .offline
            .rate(c, opps[c.0], Family::CacheAware, s.class)
            .expect("offline table covers its own descriptor");
        rates.push_row(vec![
            soc[c].name.clone(),
            format!("{ana:.3}"),
            live_r.map_or("cold".to_string(), |r| format!("{r:.3}")),
            s.report.table.samples(c, opps[c.0], Family::CacheAware, s.class).to_string(),
            format!("{off_r:.3}"),
            live_r.map_or("-".to_string(), |r| format!("{:.3}", r / off_r)),
        ]);
    }

    // --- Table 2: the CA-SAS shares under each source. ---
    let ana_w = WeightSource::Analytical.weights(model, true, s.class).normalized();
    let live_w = WeightSource::Live {
        table: s.report.table.clone(),
        min_samples: s.cfg.min_samples,
    }
    .weights(model, true, s.class)
    .normalized();
    let emp_w = WeightSource::Empirical(s.offline.clone())
        .weights(model, true, s.class)
        .normalized();
    let mut weights = Table::new(
        &format!("CA-SAS weight shares by source — class {}", s.class.label()),
        &["source", "big share", "LITTLE share", "Δ vs offline empirical [pp]"],
    );
    for (label, w) in [
        ("analytical", &ana_w),
        ("live (learned)", &live_w),
        ("offline empirical", &emp_w),
    ] {
        weights.push_row(vec![
            label.to_string(),
            format!("{:.4}", w.share(0)),
            format!("{:.4}", w.share(1)),
            format!("{:+.2}", (w.share(0) - emp_w.share(0)) * 100.0),
        ]);
    }

    // --- Table 3: the stream replay, baseline vs live. ---
    let mut stream = Table::new(
        &format!(
            "Analytical CA-SAS vs live-calibrating replay — exynos5422, {} staggered arrivals",
            s.live.requests
        ),
        STREAM_COLUMNS,
    );
    stream.push_row(stream_row(&s.analytical));
    stream.push_row(stream_row(&s.live));

    // --- Table 4: the learning trace. ---
    let mut learning = Table::new("Live-calibration trace", &["knob / outcome", "value"]);
    for (k, v) in [
        ("EWMA half-life [events]", format!("{}", s.cfg.half_life_events)),
        ("confidence gate [samples/cell]", s.cfg.min_samples.to_string()),
        ("re-plan period [grabs]", s.cfg.replan_every.to_string()),
        ("observations accepted", s.report.table.accepted().to_string()),
        ("observations rejected", s.report.table.rejected().to_string()),
        ("cells learned", s.report.table.num_cells().to_string()),
        (
            "warmup [accepted events]",
            s.report.warmup_events.map_or("never".to_string(), |w| w.to_string()),
        ),
        ("re-plans applied", s.report.replans.to_string()),
        ("share convergence error [pp]", format!("{:.3}", s.convergence_pct)),
    ] {
        learning.push_row(vec![k.to_string(), v]);
    }

    // Determinism: the live replay is a pure fold over its own event
    // sequence — a second run (own cache, own table) must agree bit
    // for bit, stats and learned tables alike.
    let arrivals = pinned_live_arrivals(quick);
    let (live2, reports2) = simulate_fleet_stream_live(&fleet, &arrivals, s.cfg);
    // Frozen-snapshot contract: once every learned cell is confident,
    // freezing the table into a RateTable and replaying through the
    // Empirical source reproduces the Live weights exactly.
    let snap_w = WeightSource::Empirical(s.report.table.snapshot(soc, s.cfg.min_samples))
        .weights(model, true, s.class)
        .normalized();

    let assertions = vec![
        Assertion::check(
            "the board warms up mid-stream",
            s.report.warmup_events.is_some(),
            format!(
                "{} accepted observations over {} cells, gate {}",
                s.report.table.accepted(),
                s.report.table.num_cells(),
                s.cfg.min_samples
            ),
        ),
        Assertion::check(
            "learned shares converge to the offline empirical shares (< 5 pp)",
            s.convergence_pct < 5.0,
            format!(
                "live big share {:.4} vs offline {:.4} ({:.3} pp off)",
                live_w.share(0),
                emp_w.share(0),
                s.convergence_pct
            ),
        ),
        // The acceptance criterion: learning while serving must not
        // lose to the frozen analytical baseline it bootstrapped from.
        // Tolerance is one coarse-split stride per re-plan (the Loop-1
        // split aligns to `nr` columns), same as the offline
        // calibration report's.
        Assertion::check(
            "live CA-SAS >= analytical CA-SAS after warmup",
            s.live.gflops >= s.analytical.gflops * (1.0 - 5e-3),
            format!(
                "live {:.3} vs analytical {:.3} GFLOPS",
                s.live.gflops, s.analytical.gflops
            ),
        ),
        Assertion::check(
            "mid-stream re-planning engages",
            s.report.replans >= 1,
            format!("{} re-plans over {} requests", s.report.replans, s.live.requests),
        ),
        Assertion::check(
            "a clean replay rejects nothing",
            s.report.table.rejected() == 0 && s.report.table.accepted() > 0,
            format!(
                "{} accepted, {} rejected",
                s.report.table.accepted(),
                s.report.table.rejected()
            ),
        ),
        Assertion::check(
            "the live replay is bit-for-bit deterministic",
            live2 == s.live && reports2 == vec![s.report.clone()],
            "second replay (fresh cache, fresh table) compared equal".to_string(),
        ),
        Assertion::check(
            "the frozen snapshot reproduces the live weights through the empirical source",
            snap_w.as_slice() == live_w.as_slice(),
            format!("snapshot {:?} vs live {:?}", snap_w.as_slice(), live_w.as_slice()),
        ),
    ];

    FigureResult {
        id: "live",
        title: "Live calibration: rates learned from the serving path, and the re-planned split",
        tables: vec![rates, weights, stream, learning],
        assertions,
    }
}

#[cfg(test)]
mod tests {
    #[test]
    fn live_report_passes_quick() {
        let fig = super::run(true);
        assert!(fig.passed(), "{}", fig.to_markdown());
        assert_eq!(fig.tables.len(), 4);
        assert_eq!(fig.id, "live");
    }

    /// The pinned scenario is stable across calls — the precondition
    /// of the trajectory rows built on it.
    #[test]
    fn pinned_live_scenario_is_deterministic() {
        let a = super::pinned_live_arrivals(true);
        let b = super::pinned_live_arrivals(true);
        assert_eq!(a, b);
        assert_eq!(a.len(), 48);
        assert_eq!(super::pinned_live_arrivals(false).len(), 96);
        assert_eq!(super::pinned_live_fleet().num_boards(), 1);
    }
}
