//! Fig. 7: the architecture-oblivious SSS configuration (coarse Loop 1 +
//! fine Loop 4, A15 parameters everywhere) against the isolated clusters
//! and the Ideal aggregate. Paper finding (§4): SSS on all 8 cores
//! reaches only ≈ 40 % of the A15-only peak and has the worst energy
//! efficiency of any configuration.

use crate::figures::{ideal_gflops, sim_square, sizes, Assertion, FigureResult};
use crate::model::PerfModel;
use crate::sched::ScheduleSpec;
use crate::soc::{BIG, LITTLE};
use crate::util::table::Table;

pub fn run(model: &PerfModel, quick: bool) -> FigureResult {
    let rs = sizes(quick);
    let mut perf = Table::new(
        "Fig7 performance [GFLOPS]",
        &["r", "SSS(8 cores)", "A15x4", "A7x4", "Ideal"],
    );
    let mut eff = Table::new(
        "Fig7 energy efficiency [GFLOPS/W]",
        &["r", "SSS(8 cores)", "A15x4", "A7x4"],
    );

    let mut last = (0.0, 0.0, 0.0); // (sss, a15, ideal) at largest r
    let mut sss_eff_worst_everywhere = true;
    for &r in &rs {
        let sss = sim_square(model, &ScheduleSpec::sss(), r);
        let a15 = sim_square(model, &ScheduleSpec::cluster_only(BIG, 4), r);
        let a7 = sim_square(model, &ScheduleSpec::cluster_only(LITTLE, 4), r);
        let ideal = ideal_gflops(model, r);
        perf.push_f64_row(&[r as f64, sss.gflops, a15.gflops, a7.gflops, ideal], 3);
        eff.push_f64_row(
            &[r as f64, sss.gflops_per_watt, a15.gflops_per_watt, a7.gflops_per_watt],
            3,
        );
        if sss.gflops_per_watt >= a15.gflops_per_watt
            || sss.gflops_per_watt >= a7.gflops_per_watt
        {
            sss_eff_worst_everywhere = false;
        }
        last = (sss.gflops, a15.gflops, ideal);
    }

    let frac = last.0 / last.1;
    let assertions = vec![
        Assertion::check(
            "SSS ≈ 40 % of the A15-only peak (§4)",
            (0.32..0.50).contains(&frac),
            format!("SSS {:.2} / A15x4 {:.2} = {:.0} % (paper ≈40 %)", last.0, last.1, frac * 100.0),
        ),
        Assertion::check(
            "SSS far below Ideal",
            last.0 < 0.45 * last.2,
            format!("SSS {:.2} vs Ideal {:.2}", last.0, last.2),
        ),
        Assertion::check(
            "SSS is the worst energy configuration at every size (§4)",
            sss_eff_worst_everywhere,
            "SSS GFLOPS/W below both isolated clusters across sizes".to_string(),
        ),
    ];

    FigureResult {
        id: "fig7",
        title: "Architecture-oblivious SSS vs isolated clusters and Ideal",
        tables: vec![perf, eff],
        assertions,
    }
}
