//! Fig. 12: the dynamic schedulers — CA-DAS and DAS (coarse Loop 3
//! dynamic, fine Loop 4 or Loop 5) against the best CA-SAS (ratio 5).
//! Paper findings (§5.4.1): CA-DAS with Loop 4 is the best overall; the
//! two-control-tree version matters a lot (DAS suffers load imbalance
//! from its uniform chunk size); Loop-5 fine grain falls behind the
//! static approach.

use crate::figures::{ideal_gflops, sim_square, sizes, Assertion, FigureResult};
use crate::model::PerfModel;
use crate::sched::{CoarseLoop, FineLoop, ScheduleSpec, Strategy};
use crate::util::table::Table;

pub fn run(model: &PerfModel, quick: bool) -> FigureResult {
    let rs = sizes(quick);
    let series: Vec<(&str, ScheduleSpec)> = vec![
        ("CA-DAS L4", ScheduleSpec::new(Strategy::CaDas, CoarseLoop::Loop3, FineLoop::Loop4)),
        ("CA-DAS L5", ScheduleSpec::new(Strategy::CaDas, CoarseLoop::Loop3, FineLoop::Loop5)),
        ("DAS L4", ScheduleSpec::new(Strategy::Das, CoarseLoop::Loop3, FineLoop::Loop4)),
        ("DAS L5", ScheduleSpec::new(Strategy::Das, CoarseLoop::Loop3, FineLoop::Loop5)),
        ("CA-SAS(r=5) L4", ScheduleSpec::ca_sas(5.0)),
    ];
    let mut cols = vec!["r"];
    cols.extend(series.iter().map(|(n, _)| *n));
    cols.push("Ideal");
    let mut perf = Table::new("Fig12 dynamic schedulers, performance [GFLOPS]", &cols);
    let mut eff = Table::new("Fig12 dynamic schedulers, energy [GFLOPS/W]", &cols);

    let r_max = *rs.last().unwrap();
    let mut at_max = vec![0.0f64; series.len()];
    let mut eff_at_max = vec![0.0f64; series.len()];
    // Per-size CA-DAS/DAS gap: the paper's "severe load unbalance for
    // certain problem sizes" (§5.4.1) — the DAS deficit is size-dependent
    // (it shrinks as the chunk count amortizes the uniform-chunk tail).
    let mut das_gap = Vec::new();
    for &r in &rs {
        let mut prow = vec![r as f64];
        let mut erow = vec![r as f64];
        let mut row_g = vec![0.0f64; series.len()];
        for (i, (_, spec)) in series.iter().enumerate() {
            let st = sim_square(model, spec, r);
            prow.push(st.gflops);
            erow.push(st.gflops_per_watt);
            row_g[i] = st.gflops;
            if r == r_max {
                at_max[i] = st.gflops;
                eff_at_max[i] = st.gflops_per_watt;
            }
        }
        das_gap.push(row_g[0] / row_g[2]);
        prow.push(ideal_gflops(model, r));
        erow.push(f64::NAN);
        perf.push_f64_row(&prow, 3);
        eff.push_f64_row(&erow, 3);
    }
    let max_gap = das_gap.iter().cloned().fold(0.0, f64::max);
    let min_gap = das_gap.iter().cloned().fold(f64::INFINITY, f64::min);

    let ideal = ideal_gflops(model, r_max);
    let assertions = vec![
        Assertion::check(
            "CA-DAS + Loop 4 is the best configuration (§5.4.1)",
            at_max[0] >= at_max.iter().cloned().fold(0.0, f64::max) - 1e-9,
            format!("{:?}", at_max),
        ),
        Assertion::check(
            "two control trees matter: CA-DAS ≥ DAS everywhere, with a \
             severe DAS deficit at some sizes (§5.4.1)",
            min_gap > 0.99 && max_gap > 1.05,
            format!("CA-DAS/DAS gap across sizes: min {min_gap:.3}, max {max_gap:.3}"),
        ),
        Assertion::check(
            "CA-DAS L4 matches/beats the best static CA-SAS",
            at_max[0] > 0.97 * at_max[4],
            format!("CA-DAS {:.2} vs CA-SAS(r=5) {:.2}", at_max[0], at_max[4]),
        ),
        Assertion::check(
            "Loop-5 dynamic falls behind the static approach (§5.4.1)",
            at_max[1] < at_max[4],
            format!("CA-DAS L5 {:.2} vs CA-SAS {:.2}", at_max[1], at_max[4]),
        ),
        Assertion::check(
            "CA-DAS approaches the ideal",
            at_max[0] > 0.90 * ideal,
            format!("{:.2} vs ideal {:.2}", at_max[0], ideal),
        ),
        Assertion::check(
            "CA-DAS also best on energy among dynamic variants",
            eff_at_max[0] >= eff_at_max[1].max(eff_at_max[2]).max(eff_at_max[3]) - 1e-9,
            format!("{:?}", eff_at_max),
        ),
    ];

    FigureResult {
        id: "fig12",
        title: "Dynamic CA-DAS / DAS vs best CA-SAS",
        tables: vec![perf, eff],
        assertions,
    }
}
