//! Task-DAG factorization report (ISSUE 10, no paper counterpart — the
//! §6 "task-level scheduling" future-work item, via arXiv:1509.02058's
//! criticality-aware recipe): what scheduling a *graph* of tiled
//! kernels architecture-aware buys on an asymmetric SoC, and that the
//! unified [`JobSpec`] workload API really carries mixed GEMM +
//! factorization streams end to end.
//!
//! Three tables:
//! 1. **blocked factorizations** — criticality-aware vs
//!    cluster-oblivious schedules of blocked Cholesky and LU on the
//!    exynos5422 (n = 1024, nb = 128): makespan, effective GFLOPS,
//!    energy, critical-path bound;
//! 2. **mixed-job stream** — a pinned Poisson stream interleaving
//!    square GEMMs with `Factor` jobs through the one [`StreamSim`]
//!    DES, on the fleet report's columns;
//! 3. **coordinator round-trip** — a real TCP server served GEMM and
//!    `JOB chol`/`JOB lu` requests over one connection, checksums
//!    replayed for determinism.
//!
//! The acceptance criteria (ISSUE 10): criticality-aware blocked
//! Cholesky beats the asymmetry-oblivious schedule by ≥ 5 % on the
//! exynos5422, the mixed stream executes exactly once with the per-job
//! histogram merging in submission order, and the wire protocol serves
//! factorizations next to GEMMs on one connection.

use crate::blis::gemm::GemmShape;
use crate::calibrate::{ShapeClass, WeightSource};
use crate::coordinator::server::{serve, Client};
use crate::coordinator::Coordinator;
use crate::dag::{
    schedule, tile_costs, DagPolicy, DagSchedule, FactorKind, JobSpec, TaskGraph,
};
use crate::figures::fleet::{stream_row, STREAM_COLUMNS};
use crate::figures::{Assertion, FigureResult};
use crate::fleet::sim::{poisson_job_arrivals, Arrival, StreamSim, StreamStats};
use crate::fleet::Fleet;
use crate::model::PerfModel;
use crate::sim::RunCache;
use crate::util::rng::Rng;
use crate::util::table::Table;
use std::sync::Arc;

/// The pinned factorization descriptor: n = 1024 in nb = 128 tiles —
/// an 8 × 8 tile grid, large enough that trailing updates dominate and
/// placement quality shows, small enough to schedule instantly.
pub const PINNED_N: usize = 1024;
pub const PINNED_NB: usize = 128;

/// Schedule the pinned blocked Cholesky both ways on the exynos5422:
/// `(criticality-aware, oblivious)`. Pure virtual time (one DES run
/// per cluster for the tile costs), deterministic — the subject of the
/// `dag_cholesky_speedup` trajectory row.
pub fn pinned_cholesky_pair() -> (DagSchedule, DagSchedule) {
    pinned_pair(FactorKind::Cholesky)
}

fn pinned_pair(kind: FactorKind) -> (DagSchedule, DagSchedule) {
    let model = PerfModel::exynos();
    let graph = TaskGraph::build(kind, PINNED_N, PINNED_NB);
    let mut cache = RunCache::new();
    let costs = tile_costs(&model, PINNED_NB, &mut cache);
    let class = ShapeClass::for_soc(&model.soc, GemmShape::square(PINNED_NB));
    let w = WeightSource::Analytical.weights(&model, true, class);
    (
        schedule(&graph, &costs, &w, DagPolicy::CriticalityAware),
        schedule(&graph, &costs, &w, DagPolicy::Oblivious),
    )
}

/// The pinned mixed-job stream: two square GEMM sizes interleaved with
/// a blocked Cholesky and a blocked LU, Poisson arrivals above the
/// board's capacity so the replay is service-bound. Deterministic
/// (seeded [`Rng`]); `quick` halves the stream length.
pub fn pinned_mixed_arrivals(quick: bool) -> Vec<Arrival> {
    let jobs = [
        JobSpec::Gemm(GemmShape::square(384)),
        JobSpec::Gemm(GemmShape::square(512)),
        JobSpec::Factor { kind: FactorKind::Cholesky, n: 512, nb: 128 },
        JobSpec::Factor { kind: FactorKind::Lu, n: 384, nb: 128 },
    ];
    let count = if quick { 32 } else { 64 };
    let mut rng = Rng::new(0xDA6_F10);
    poisson_job_arrivals(&mut rng, &jobs, count, 60.0)
}

/// One exynos5422 board under its preset schedule — factorization
/// tiles price through the same weight source as the GEMMs.
pub fn pinned_mixed_fleet() -> Fleet {
    Fleet::parse("exynos5422").expect("preset")
}

/// Replay the pinned mixed stream through the consolidated
/// [`StreamSim`] entry point — the `dag_stream_mixed_p99` trajectory
/// row and the report's table 2.
pub fn mixed_stream_summary(quick: bool) -> StreamStats {
    StreamSim::new(&pinned_mixed_fleet()).run(&pinned_mixed_arrivals(quick))
}

fn factor_row(kind: FactorKind, graph: &TaskGraph, s: &DagSchedule) -> Vec<String> {
    vec![
        format!("{} n={} nb={}", kind.label(), graph.n, graph.nb),
        s.policy.label().to_string(),
        format!("{:.4}", s.makespan_s),
        format!("{:.3}", s.gflops(graph)),
        format!("{:.2}", s.energy_j),
        format!("{:.4}", s.critical_path_s),
        s.critical_tasks.to_string(),
    ]
}

pub fn run(quick: bool) -> FigureResult {
    // --- Table 1: the schedule pair, Cholesky and LU. ---
    let mut factor = Table::new(
        "Blocked factorizations on the exynos5422 — criticality-aware vs cluster-oblivious",
        &["factorization", "policy", "makespan [s]", "GFLOPS", "energy [J]",
          "critical path [s]", "critical tasks"],
    );
    let chol_graph = TaskGraph::cholesky(PINNED_N, PINNED_NB);
    let (chol_ca, chol_obl) = pinned_cholesky_pair();
    factor.push_row(factor_row(FactorKind::Cholesky, &chol_graph, &chol_ca));
    factor.push_row(factor_row(FactorKind::Cholesky, &chol_graph, &chol_obl));
    let lu_graph = TaskGraph::lu(PINNED_N, PINNED_NB);
    let (lu_ca, lu_obl) = pinned_pair(FactorKind::Lu);
    factor.push_row(factor_row(FactorKind::Lu, &lu_graph, &lu_ca));
    factor.push_row(factor_row(FactorKind::Lu, &lu_graph, &lu_obl));
    let chol_speedup = chol_obl.makespan_s / chol_ca.makespan_s;

    // --- Table 2: the mixed-job stream through StreamSim. ---
    let arrivals = pinned_mixed_arrivals(quick);
    let mixed = mixed_stream_summary(quick);
    let mut stream = Table::new(
        &format!(
            "Mixed GEMM + factorization stream — exynos5422, {} Poisson arrivals",
            mixed.requests
        ),
        STREAM_COLUMNS,
    );
    stream.push_row(stream_row(&mixed));
    // Submitted histogram in first-submission order — what `per_job`
    // must merge back to.
    let mut submitted: Vec<(JobSpec, usize)> = Vec::new();
    for a in &arrivals {
        match submitted.iter_mut().find(|(j, _)| *j == a.job) {
            Some((_, c)) => *c += 1,
            None => submitted.push((a.job, 1)),
        }
    }

    // --- Table 3: the wire protocol, GEMMs and factorizations on one
    //     connection against a real TCP server. Sizes are small — this
    //     is a protocol round-trip, not a benchmark. ---
    let coord = Arc::new(Coordinator::new(crate::soc::SocSpec::exynos5422()));
    let handle = serve(coord, "127.0.0.1:0").expect("ephemeral server");
    let mut client = Client::connect(handle.addr).expect("client connect");
    let mut wire = Table::new(
        "Coordinator round-trip — interleaved GEMM and JOB requests, one connection",
        &["request", "reply ok", "label", "checksum replays"],
    );
    let mut wire_ok = true;
    for line in ["GEMM 64 64 64 7 native", "JOB chol 96 32 7", "JOB gemm 64 64 64 7 native",
                 "JOB lu 96 32 7"] {
        let r1 = client.call(line).expect("call");
        let r2 = client.call(line).expect("replay");
        let ok = r1.starts_with("OK ");
        let nth = |r: &str, i: usize| r.split_whitespace().nth(i).map(str::to_string);
        let replays = ok && nth(&r1, 4) == nth(&r2, 4);
        wire_ok &= ok && replays;
        wire.push_row(vec![
            line.to_string(),
            ok.to_string(),
            nth(&r1, 5).unwrap_or_else(|| r1.clone()),
            replays.to_string(),
        ]);
    }
    let help = client.call("HELP").expect("help");
    let unknown = client.call("JOB qr 96 32 1").expect("unknown job");
    handle.shutdown();

    let assertions = vec![
        Assertion::check(
            "criticality-aware blocked Cholesky beats oblivious by >= 5%",
            chol_speedup >= 1.05,
            format!(
                "CA {:.4}s vs oblivious {:.4}s ({:.1}% faster)",
                chol_ca.makespan_s,
                chol_obl.makespan_s,
                (chol_speedup - 1.0) * 100.0
            ),
        ),
        Assertion::check(
            "criticality-aware LU beats oblivious too",
            lu_ca.makespan_s < lu_obl.makespan_s,
            format!("CA {:.4}s vs oblivious {:.4}s", lu_ca.makespan_s, lu_obl.makespan_s),
        ),
        Assertion::check(
            "no schedule beats its critical-path bound",
            chol_ca.makespan_s >= chol_ca.critical_path_s - 1e-12
                && lu_ca.makespan_s >= lu_ca.critical_path_s - 1e-12,
            format!(
                "chol {:.4} >= {:.4}, lu {:.4} >= {:.4}",
                chol_ca.makespan_s,
                chol_ca.critical_path_s,
                lu_ca.makespan_s,
                lu_ca.critical_path_s
            ),
        ),
        Assertion::check(
            "the mixed stream executes every job exactly once",
            mixed.items_completed() == mixed.requests
                && mixed.completions.len() == mixed.requests
                && mixed.completions.iter().all(|c| c.is_finite()),
            format!("{}/{} requests completed", mixed.items_completed(), mixed.requests),
        ),
        Assertion::check(
            "per-job stats merge back to the submitted histogram in submission order",
            mixed.per_job == submitted,
            format!("executed {:?} vs submitted {:?}", mixed.per_job, submitted),
        ),
        Assertion::check(
            "the mixed stream replays bit-for-bit",
            mixed == mixed_stream_summary(quick),
            "second replay (fresh cache) compared equal".to_string(),
        ),
        Assertion::check(
            "GEMM and JOB requests round-trip the wire with deterministic checksums",
            wire_ok,
            format!("{} interleaved requests on one connection", wire.rows.len()),
        ),
        Assertion::check(
            "HELP lists the JOB family; unknown kinds get a structured error",
            help.starts_with("OK commands:")
                && help.contains("JOB chol")
                && unknown == "ERR unknown_job qr",
            format!("HELP -> '{help}', JOB qr -> '{unknown}'"),
        ),
    ];

    FigureResult {
        id: "dag",
        title: "Task-DAG factorizations: criticality-aware scheduling and the unified job API",
        tables: vec![factor, stream, wire],
        assertions,
    }
}

#[cfg(test)]
mod tests {
    #[test]
    fn dag_report_passes_quick() {
        let fig = super::run(true);
        assert!(fig.passed(), "{}", fig.to_markdown());
        assert_eq!(fig.tables.len(), 3);
        assert_eq!(fig.id, "dag");
    }

    /// The pinned inputs behind the trajectory rows are stable across
    /// calls.
    #[test]
    fn pinned_dag_scenario_is_deterministic() {
        let (ca1, obl1) = super::pinned_cholesky_pair();
        let (ca2, obl2) = super::pinned_cholesky_pair();
        assert_eq!(ca1, ca2);
        assert_eq!(obl1, obl2);
        let a = super::pinned_mixed_arrivals(true);
        assert_eq!(a, super::pinned_mixed_arrivals(true));
        assert_eq!(a.len(), 32);
        assert_eq!(super::pinned_mixed_arrivals(false).len(), 64);
    }
}
