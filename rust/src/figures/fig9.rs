//! Fig. 9: SAS (coarse Loop 1 + fine Loop 4) with distribution ratios
//! 1–7. Paper findings (§5.2.2): worst at ratio 1; performance grows up
//! to ratio 5–6 then declines; at the largest size the best ratio beats
//! the A15-only configuration by ≈ 20 %; small problems cannot exploit
//! the asymmetry; a well-balanced SAS matches A15-only GFLOPS/W while
//! unbalanced ratios crater it.

use crate::figures::{ideal_gflops, sim_square, sizes, Assertion, FigureResult};
use crate::model::PerfModel;
use crate::sched::ScheduleSpec;
use crate::soc::BIG;
use crate::util::table::Table;

pub fn run(model: &PerfModel, quick: bool) -> FigureResult {
    let rs = sizes(quick);
    let ratios: Vec<usize> = (1..=7).collect();
    let mut cols = vec!["r".to_string()];
    cols.extend(ratios.iter().map(|r| format!("SAS(r={r})")));
    cols.push("A15x4".into());
    cols.push("Ideal".into());
    let col_refs: Vec<&str> = cols.iter().map(|s| s.as_str()).collect();
    let mut perf = Table::new("Fig9 SAS ratio sweep, performance [GFLOPS]", &col_refs);
    let mut eff = Table::new("Fig9 SAS ratio sweep, energy [GFLOPS/W]", &col_refs);

    let r_max = *rs.last().unwrap();
    let mut big_curve = Vec::new(); // gflops by ratio at r_max
    let mut eff_curve = Vec::new();
    let mut a15_at_max = (0.0, 0.0);
    for &r in &rs {
        let mut prow = vec![r as f64];
        let mut erow = vec![r as f64];
        for &ratio in &ratios {
            let st = sim_square(model, &ScheduleSpec::sas(ratio as f64), r);
            prow.push(st.gflops);
            erow.push(st.gflops_per_watt);
            if r == r_max {
                big_curve.push(st.gflops);
                eff_curve.push(st.gflops_per_watt);
            }
        }
        let a15 = sim_square(model, &ScheduleSpec::cluster_only(BIG, 4), r);
        prow.push(a15.gflops);
        prow.push(ideal_gflops(model, r));
        erow.push(a15.gflops_per_watt);
        erow.push(f64::NAN);
        if r == r_max {
            a15_at_max = (a15.gflops, a15.gflops_per_watt);
        }
        perf.push_f64_row(&prow, 3);
        eff.push_f64_row(&erow, 3);
    }

    let best_ratio = 1 + big_curve
        .iter()
        .enumerate()
        .max_by(|a, b| a.1.total_cmp(b.1))
        .unwrap()
        .0;
    let best = big_curve[best_ratio - 1];
    let mut assertions = vec![
        Assertion::check(
            "performance peaks at ratio 5–6 (§5.2.2)",
            (5..=6).contains(&best_ratio),
            format!("best ratio {best_ratio}; curve {big_curve:?}"),
        ),
        Assertion::check(
            "ratio 1 (homogeneous) is the worst",
            big_curve.iter().skip(1).all(|&g| g > big_curve[0]),
            format!("r=1 gives {:.2} GFLOPS", big_curve[0]),
        ),
        Assertion::check(
            "best SAS ≈ +20 % over A15-only at the largest size",
            (1.10..1.30).contains(&(best / a15_at_max.0)),
            format!("{:.2} vs {:.2} (+{:.0} %)", best, a15_at_max.0, (best / a15_at_max.0 - 1.0) * 100.0),
        ),
        Assertion::check(
            "declines above ratio 6 but stays above the r=1 floor",
            big_curve[6] < best && big_curve[6] > big_curve[0],
            format!("r=7: {:.2}", big_curve[6]),
        ),
        Assertion::check(
            "balanced SAS matches A15-only energy efficiency (§5.2.2)",
            (eff_curve[best_ratio - 1] / a15_at_max.1 - 1.0).abs() < 0.20,
            format!("{:.3} vs {:.3}", eff_curve[best_ratio - 1], a15_at_max.1),
        ),
        Assertion::check(
            "unbalanced ratio 1 craters energy efficiency",
            eff_curve[0] < 0.7 * eff_curve[best_ratio - 1],
            format!("r=1 {:.3} vs best {:.3}", eff_curve[0], eff_curve[best_ratio - 1]),
        ),
    ];

    // Small-size claim: the best large-size ratio underperforms at small r.
    let small = sim_square(model, &ScheduleSpec::sas(best_ratio as f64), rs[0]);
    assertions.push(Assertion::check(
        "small problems cannot exploit the asymmetry",
        small.gflops < 0.85 * best,
        format!("r={}: {:.2} vs r={}: {:.2}", rs[0], small.gflops, r_max, best),
    ));

    FigureResult {
        id: "fig9",
        title: "SAS with distribution ratios 1–7 (Loop 1 + Loop 4)",
        tables: vec![perf, eff],
        assertions,
    }
}
