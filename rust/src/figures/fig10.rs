//! Fig. 10: SAS vs CA-SAS (one vs two control trees) at distribution
//! ratios 1, 3, 5 (coarse Loop 1 + fine Loop 4). Paper findings (§5.3.1):
//! the two-control-tree version wins on both metrics, with the gains
//! visible only when too much work lands on the A7 cluster (ratios < 5);
//! at ratio 5 the curves coincide.

use crate::figures::{sim_square, sizes, Assertion, FigureResult};
use crate::model::PerfModel;
use crate::sched::ScheduleSpec;
use crate::util::table::Table;

pub fn run(model: &PerfModel, quick: bool) -> FigureResult {
    let rs = sizes(quick);
    let ratios = [1.0, 3.0, 5.0];
    let mut cols = vec!["r".to_string()];
    for r in ratios {
        cols.push(format!("SAS(r={r:.0})"));
        cols.push(format!("CA-SAS(r={r:.0})"));
    }
    let col_refs: Vec<&str> = cols.iter().map(|s| s.as_str()).collect();
    let mut perf = Table::new("Fig10 SAS vs CA-SAS, performance [GFLOPS]", &col_refs);
    let mut eff = Table::new("Fig10 SAS vs CA-SAS, energy [GFLOPS/W]", &col_refs);

    let r_max = *rs.last().unwrap();
    let mut at_max = Vec::new(); // (sas, casas, sas_eff, casas_eff) per ratio
    for &r in &rs {
        let mut prow = vec![r as f64];
        let mut erow = vec![r as f64];
        for &ratio in &ratios {
            let sas = sim_square(model, &ScheduleSpec::sas(ratio), r);
            let ca = sim_square(model, &ScheduleSpec::ca_sas(ratio), r);
            prow.extend([sas.gflops, ca.gflops]);
            erow.extend([sas.gflops_per_watt, ca.gflops_per_watt]);
            if r == r_max {
                at_max.push((sas.gflops, ca.gflops, sas.gflops_per_watt, ca.gflops_per_watt));
            }
        }
        perf.push_f64_row(&prow, 3);
        eff.push_f64_row(&erow, 3);
    }

    let assertions = vec![
        Assertion::check(
            "CA-SAS clearly better at ratio 1 (work-heavy A7, §5.3.1)",
            at_max[0].1 > 1.05 * at_max[0].0,
            format!("CA {:.2} vs SAS {:.2}", at_max[0].1, at_max[0].0),
        ),
        Assertion::check(
            "CA-SAS clearly better at ratio 3",
            at_max[1].1 > 1.05 * at_max[1].0,
            format!("CA {:.2} vs SAS {:.2}", at_max[1].1, at_max[1].0),
        ),
        Assertion::check(
            "no visible difference at ratio 5 (big cluster critical)",
            (at_max[2].1 / at_max[2].0 - 1.0).abs() < 0.05,
            format!("CA {:.2} vs SAS {:.2}", at_max[2].1, at_max[2].0),
        ),
        Assertion::check(
            "CA-SAS never worse on energy",
            at_max.iter().all(|t| t.3 >= t.2 * 0.98),
            format!("pairs (SAS, CA) eff: {:?}", at_max.iter().map(|t| (t.2, t.3)).collect::<Vec<_>>()),
        ),
        Assertion::check(
            "CA-SAS gains shrink as the ratio grows",
            (at_max[0].1 / at_max[0].0) > (at_max[1].1 / at_max[1].0)
                && (at_max[1].1 / at_max[1].0) > (at_max[2].1 / at_max[2].0),
            format!(
                "gains: r1 {:.2}×, r3 {:.2}×, r5 {:.2}×",
                at_max[0].1 / at_max[0].0,
                at_max[1].1 / at_max[1].0,
                at_max[2].1 / at_max[2].0
            ),
        ),
    ];

    FigureResult {
        id: "fig10",
        title: "SAS vs CA-SAS at ratios 1, 3, 5",
        tables: vec![perf, eff],
        assertions,
    }
}
