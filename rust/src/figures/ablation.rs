//! Ablations beyond the paper's evaluation — its §6 roadmap, made
//! runnable:
//!
//! * **core-count ablation** ("architectures with different number of
//!   big/LITTLE cores"): CA-DAS and the best SAS on 2+4 / 4+4 / 2+6 /
//!   6+2 configurations;
//! * **DVFS ablation** (§5.2's ratio knob under frequency changes):
//!   the model-derived SAS ratio and the CA-DAS robustness across
//!   operating points;
//! * **ARMv8 port** (Juno r0 descriptor): the schedulers on a 2×A57 +
//!   4×A53 machine, no recalibration;
//! * **per-core micro-kernels** ("different micro-kernels, tuned to
//!   each type of core"): the modelled effect of an 8×4 big-core
//!   register block.

use crate::blis::gemm::GemmShape;
use crate::blis::params::BlisParams;
use crate::figures::{Assertion, FigureResult};
use crate::model::PerfModel;
use crate::sched::ScheduleSpec;
use crate::sim::simulate;
use crate::soc::{SocSpec, BIG, LITTLE};
use crate::util::table::Table;

pub fn run(_quick: bool) -> FigureResult {
    let r = 4096;
    let mut tables = Vec::new();
    let mut assertions = Vec::new();

    // ---- 1. core counts --------------------------------------------
    let mut t1 = Table::new(
        "Ablation: big+LITTLE core counts (r = 4096)",
        &["config", "ideal", "CA-DAS", "% of ideal", "best SAS ratio", "SAS @ best"],
    );
    for (nb, nl) in [(2usize, 4usize), (4, 4), (2, 6), (6, 2)] {
        let model = PerfModel::new(SocSpec::custom_counts(nb, nl));
        let ideal = simulate(&model, &ScheduleSpec::cluster_only(BIG, nb), GemmShape::square(r)).gflops
            + simulate(&model, &ScheduleSpec::cluster_only(LITTLE, nl), GemmShape::square(r)).gflops;
        let cadas = simulate(&model, &ScheduleSpec::ca_das(), GemmShape::square(r)).gflops;
        let (mut best_ratio, mut best_g) = (1, 0.0);
        for ratio in 1..=12 {
            let g = simulate(&model, &ScheduleSpec::sas(ratio as f64), GemmShape::square(r)).gflops;
            if g > best_g {
                best_g = g;
                best_ratio = ratio;
            }
        }
        t1.push_row(vec![
            format!("{nb}+{nl}"),
            format!("{ideal:.2}"),
            format!("{cadas:.2}"),
            format!("{:.0}%", cadas / ideal * 100.0),
            best_ratio.to_string(),
            format!("{best_g:.2}"),
        ]);
        assertions.push(Assertion::check(
            &format!("{nb}+{nl}: CA-DAS ≥ 90 % of ideal without retuning"),
            cadas > 0.90 * ideal,
            format!("{cadas:.2} vs ideal {ideal:.2}"),
        ));
    }
    tables.push(t1);

    // ---- 2. DVFS ----------------------------------------------------
    let mut t2 = Table::new(
        "Ablation: DVFS operating points (Exynos, r = 4096)",
        &["freqs GHz (big/LITTLE)", "model ratio", "best swept SAS ratio", "CA-DAS % of ideal"],
    );
    let mut dvfs_ratios = Vec::new();
    for (fb, fl) in [(1.6, 1.4), (1.2, 1.4), (0.8, 1.4), (1.6, 0.7)] {
        let model = PerfModel::new(SocSpec::exynos5422().with_freqs(fb, fl));
        let p = BlisParams::a15_opt();
        let model_ratio = model.ideal_ratio(&p, &p);
        let (mut best_ratio, mut best_g) = (1, 0.0);
        for ratio in 1..=12 {
            let g = simulate(&model, &ScheduleSpec::sas(ratio as f64), GemmShape::square(r)).gflops;
            if g > best_g {
                best_g = g;
                best_ratio = ratio;
            }
        }
        let ideal = simulate(&model, &ScheduleSpec::cluster_only(BIG, 4), GemmShape::square(r)).gflops
            + simulate(&model, &ScheduleSpec::cluster_only(LITTLE, 4), GemmShape::square(r)).gflops;
        let cadas = simulate(&model, &ScheduleSpec::ca_das(), GemmShape::square(r)).gflops;
        t2.push_row(vec![
            format!("{fb}/{fl}"),
            format!("{model_ratio:.2}"),
            best_ratio.to_string(),
            format!("{:.0}%", cadas / ideal * 100.0),
        ]);
        dvfs_ratios.push((model_ratio, best_ratio as f64, cadas / ideal));
    }
    tables.push(t2);
    assertions.push(Assertion::check(
        "model ratio tracks the swept optimum across operating points (±1.5)",
        dvfs_ratios.iter().all(|(m, b, _)| (m - b).abs() <= 1.5),
        format!("{dvfs_ratios:?}"),
    ));
    assertions.push(Assertion::check(
        "CA-DAS needs no ratio and stays ≥ 88 % of ideal at every point",
        dvfs_ratios.iter().all(|(_, _, frac)| *frac >= 0.88),
        format!("{dvfs_ratios:?}"),
    ));

    // ---- 3. ARMv8 (Juno) --------------------------------------------
    let juno = PerfModel::new(SocSpec::juno_r0());
    let mut t3 = Table::new(
        "Ablation: ARMv8 Juno r0 (2×A57 + 4×A53, r = 4096)",
        &["schedule", "GFLOPS", "GFLOPS/W"],
    );
    let j_ideal = simulate(&juno, &ScheduleSpec::cluster_only(BIG, 2), GemmShape::square(r)).gflops
        + simulate(&juno, &ScheduleSpec::cluster_only(LITTLE, 4), GemmShape::square(r)).gflops;
    let mut j_cadas = 0.0;
    let mut j_sss = 0.0;
    for spec in [
        ScheduleSpec::cluster_only(BIG, 2),
        ScheduleSpec::cluster_only(LITTLE, 4),
        ScheduleSpec::sss(),
        ScheduleSpec::sas(3.0),
        ScheduleSpec::ca_das(),
    ] {
        let st = simulate(&juno, &spec, GemmShape::square(r));
        t3.push_row(vec![
            st.label.clone(),
            format!("{:.2}", st.gflops),
            format!("{:.3}", st.gflops_per_watt),
        ]);
        if spec == ScheduleSpec::ca_das() {
            j_cadas = st.gflops;
        }
        if spec == ScheduleSpec::sss() {
            j_sss = st.gflops;
        }
    }
    tables.push(t3);
    assertions.push(Assertion::check(
        "the scheduling story ports to ARMv8: CA-DAS ≈ ideal, ≫ SSS",
        j_cadas > 0.88 * j_ideal && j_cadas > 1.3 * j_sss,
        format!("CA-DAS {j_cadas:.2}, SSS {j_sss:.2}, ideal {j_ideal:.2}"),
    ));

    // ---- 4. per-core micro-kernels -----------------------------------
    let model = PerfModel::exynos();
    let mut t4 = Table::new(
        "Ablation: per-core-type micro-kernels (modelled single core)",
        &["core", "4x4 GFLOPS", "8x4 GFLOPS", "delta"],
    );
    let b44 = model.steady_rate_gflops(BIG, &BlisParams::a15_opt(), 1);
    let b84 = model.steady_rate_gflops(BIG, &BlisParams::a15_opt_8x4(), 1);
    let l44 = model.steady_rate_gflops(LITTLE, &BlisParams::a7_opt(), 1);
    let a7_84 = BlisParams::new(4096, 352, 80, 4, 8);
    let l84 = model.steady_rate_gflops(LITTLE, &a7_84, 1);
    t4.push_row(vec![
        "Cortex-A15".into(),
        format!("{b44:.3}"),
        format!("{b84:.3}"),
        format!("{:+.1}%", (b84 / b44 - 1.0) * 100.0),
    ]);
    t4.push_row(vec![
        "Cortex-A7".into(),
        format!("{l44:.3}"),
        format!("{l84:.3}"),
        format!("{:+.1}%", (l84 / l44 - 1.0) * 100.0),
    ]);
    tables.push(t4);
    assertions.push(Assertion::check(
        "8×4 helps the big core, hurts the LITTLE — per-core kernels pay",
        b84 > b44 && l84 < l44,
        format!("big {b44:.3}→{b84:.3}, LITTLE {l44:.3}→{l84:.3}"),
    ));

    FigureResult {
        id: "ablation",
        title: "Future-work ablations (§6): core counts, DVFS, ARMv8, per-core micro-kernels",
        tables,
        assertions,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ablation_suite_passes() {
        let fig = run(true);
        assert!(fig.passed(), "{}", fig.to_markdown());
        assert_eq!(fig.tables.len(), 4);
    }
}
