//! Calibration report (ISSUE 5, no paper counterpart — the ROADMAP
//! "weight-vector auto-tuning from the *empirical* search" item): what
//! changes when `sched::Weights` come from measured rates instead of
//! the analytical model.
//!
//! Five tables on the Exynos 5422 descriptor:
//! 1. **analytical vs measured per-cluster rates** at the nominal rung
//!    — the packing/barrier/edge overheads the analytical steady-state
//!    rate ignores, per shape class;
//! 2. **weight deltas** — the CA-SAS share vector under every
//!    [`WeightSource`], plus the degeneracy check (a table synthesized
//!    from the model reproduces the analytical shares bit for bit);
//! 3. **CA-SAS by weight source** — the DES makespan/GFLOPS with
//!    analytical, empirical and hybrid weights (the acceptance
//!    criterion: empirical ≥ analytical, because the measured ratio is
//!    the engine's own);
//! 4. **per-OPP empirical shares** feeding the DVFS online retuner —
//!    the rung-by-rung big-cluster share (not one global ratio);
//! 5. **the ondemand-ramp replay** under each source, with the
//!    empirically weighted online retune beating its own stale boot
//!    split.

use crate::blis::gemm::GemmShape;
use crate::calibrate::{ca_sas_spec, Family, RateTable, ShapeClass, WeightSource};
use crate::dvfs::sim::{simulate_dvfs_with, DvfsStrategy, Retune};
use crate::dvfs::{Governor, Ondemand};
use crate::figures::{Assertion, FigureResult};
use crate::model::PerfModel;
use crate::sim::simulate;
use crate::soc::{SocSpec, BIG};
use crate::util::table::Table;

pub fn run(quick: bool) -> FigureResult {
    let soc = SocSpec::exynos5422();
    let model = PerfModel::new(soc.clone());
    let r = if quick { 2048 } else { 4096 };
    let shape = GemmShape::square(r);
    let class = ShapeClass::for_soc(&soc, shape);

    // Calibrate on the report's own evaluation sizes: a cluster's rate
    // depends on the `k mod kc` remainder structure (a shallow trailing
    // pc block amortizes `eff_k` poorly), so measuring at the shapes
    // the schedules will actually run makes the empirical ratio the
    // engine's own for those shapes — the §4 protocol of measuring the
    // workload you intend to schedule.
    let table = RateTable::measure_with_reps(&soc, &[], &crate::calibrate::canonical_reps());
    let analytical = WeightSource::Analytical;
    let empirical = WeightSource::Empirical(table.clone());
    let hybrid = WeightSource::Hybrid(table.clone());
    let sources: [&WeightSource; 3] = [&analytical, &empirical, &hybrid];

    // --- Table 1: analytical vs measured rates at the nominal rung. ---
    let mut rates = Table::new(
        "Per-cluster rates — analytical model vs measured DES, nominal OPP",
        &["cluster", "family", "analytical", "small", "medium", "large", "large/analytical"],
    );
    for c in soc.cluster_ids() {
        let nominal = soc[c].opps.nominal_idx();
        for family in Family::ALL {
            let params = match family {
                Family::CacheAware => soc[c].tuned,
                Family::Oblivious => soc[soc.lead()].tuned,
            };
            let ana = model.cluster_rate_gflops(c, &params, soc[c].num_cores);
            let m: Vec<f64> = ShapeClass::ALL
                .iter()
                .map(|&cl| table.rate(c, nominal, family, cl).expect("measured"))
                .collect();
            rates.push_row(vec![
                soc[c].name.clone(),
                family.label().to_string(),
                format!("{ana:.3}"),
                format!("{:.3}", m[0]),
                format!("{:.3}", m[1]),
                format!("{:.3}", m[2]),
                format!("{:.3}", m[2] / ana),
            ]);
        }
    }

    // --- Table 2: the CA-SAS share vector under every source. ---
    let ana_w = analytical.weights(&model, true, class).normalized();
    let emp_w = empirical.weights(&model, true, class).normalized();
    let hyb_w = hybrid.weights(&model, true, class).normalized();
    let synth = WeightSource::Empirical(RateTable::from_analytical(&soc))
        .weights(&model, true, class)
        .normalized();
    let mut weights = Table::new(
        &format!("CA-SAS weight shares by source — class {}", class.label()),
        &["source", "big share", "LITTLE share", "big:LITTLE", "Δ vs analytical [pp]"],
    );
    for (label, w) in [
        ("analytical", &ana_w),
        ("empirical (synthesized)", &synth),
        ("empirical (measured)", &emp_w),
        ("hybrid", &hyb_w),
    ] {
        weights.push_row(vec![
            label.to_string(),
            format!("{:.4}", w.share(0)),
            format!("{:.4}", w.share(1)),
            format!("{:.2}", w.share(0) / w.share(1)),
            format!("{:+.2}", (w.share(0) - ana_w.share(0)) * 100.0),
        ]);
    }

    // --- Table 3: CA-SAS through the DES under each source. ---
    let mut casas = Table::new(
        &format!("CA-SAS by weight source — DES replay, r = {r}"),
        &["weights", "makespan [s]", "GFLOPS"],
    );
    let mut des = Vec::new();
    for source in sources {
        let st = simulate(&model, &ca_sas_spec(source, &model, class), shape);
        casas.push_row(vec![
            source.label().to_string(),
            format!("{:.3}", st.time_s),
            format!("{:.2}", st.gflops),
        ]);
        des.push(st);
    }
    let (ana_des, emp_des, hyb_des) = (&des[0], &des[1], &des[2]);

    // --- Table 4: per-OPP shares + the ondemand ramp per source. ---
    let mut per_opp = Table::new(
        "Empirical CA-SAS big-cluster share per joint OPP rung (the online retuner's input)",
        &["opp", "A15 [GHz]", "A7 [GHz]", "analytical share", "empirical share"],
    );
    let rungs = soc[BIG].opps.len();
    let mut emp_shares = Vec::new();
    for o in 0..rungs {
        let opps = vec![o; soc.num_clusters()];
        let derived = soc.at_opp(BIG, o).at_opp(crate::soc::LITTLE, o);
        let ana_o = analytical
            .weights_for(&PerfModel::new(derived.clone()), &opps, true, class)
            .normalized();
        let emp_o = empirical
            .weights_for(&PerfModel::new(derived), &opps, true, class)
            .normalized();
        per_opp.push_row(vec![
            o.to_string(),
            format!("{:.1}", soc[BIG].opps.get(o).freq_ghz),
            format!("{:.1}", soc[crate::soc::LITTLE].opps.get(o).freq_ghz),
            format!("{:.4}", ana_o.share(0)),
            format!("{:.4}", emp_o.share(0)),
        ]);
        emp_shares.push(emp_o.share(0));
    }
    let ramp = Ondemand::new(if quick { 0.25 } else { 0.5 }).plan(&soc, 1e3);
    let strat = DvfsStrategy::Sas { cache_aware: true };
    let mut dvfs = Table::new(
        "Ondemand ramp, online retuning by weight source",
        &["weights", "makespan [s]", "GFLOPS", "retunes"],
    );
    let mut ramp_stats = Vec::new();
    for source in sources {
        let st = simulate_dvfs_with(&soc, strat, shape, &ramp, Retune::Online, source);
        dvfs.push_row(vec![
            source.label().to_string(),
            format!("{:.3}", st.time_s),
            format!("{:.2}", st.gflops),
            st.retunes.to_string(),
        ]);
        ramp_stats.push(st);
    }
    let emp_boot = simulate_dvfs_with(&soc, strat, shape, &ramp, Retune::Boot, &empirical);

    let assertions = vec![
        Assertion::check(
            "measured rates sit below the analytical steady-state rates",
            {
                let mut ok = true;
                for c in soc.cluster_ids() {
                    let nominal = soc[c].opps.nominal_idx();
                    let ana = model.cluster_rate_gflops(c, &soc[c].tuned, soc[c].num_cores);
                    let m = table.rate(c, nominal, Family::CacheAware, ShapeClass::Large).unwrap();
                    ok &= m < ana && m > 0.7 * ana;
                }
                ok
            },
            "the DES pays packing/barriers the analytical rate ignores".to_string(),
        ),
        Assertion::check(
            "degeneracy: the synthesized table reproduces the analytical shares bit for bit",
            synth.as_slice() == ana_w.as_slice(),
            format!("synth {:?} vs analytical {:?}", synth.as_slice(), ana_w.as_slice()),
        ),
        Assertion::check(
            "measured weights shift the split",
            (emp_w.share(0) - ana_w.share(0)).abs() > 1e-4,
            format!(
                "empirical big share {:.4} vs analytical {:.4}",
                emp_w.share(0),
                ana_w.share(0)
            ),
        ),
        // The acceptance criterion: weights measured from the engine
        // balance the engine at least as well as the model's. The
        // tolerance is one coarse-split quantum — the Loop-1 split
        // aligns to `nr` columns, so two near-identical weight vectors
        // can land one stride apart; a stride of the slow cluster's
        // work bounds the resulting makespan wiggle.
        Assertion::check(
            "empirical CA-SAS >= analytical CA-SAS (within one split stride)",
            emp_des.gflops >= ana_des.gflops * (1.0 - 2.5e-3),
            format!("empirical {:.3} vs analytical {:.3} GFLOPS", emp_des.gflops, ana_des.gflops),
        ),
        Assertion::check(
            "hybrid CA-SAS is no worse than the worse of its parents",
            hyb_des.gflops >= ana_des.gflops.min(emp_des.gflops) * (1.0 - 2.5e-3),
            format!(
                "hybrid {:.3} vs analytical {:.3} / empirical {:.3} GFLOPS",
                hyb_des.gflops, ana_des.gflops, emp_des.gflops
            ),
        ),
        Assertion::check(
            "the empirical share is per-OPP, not one global ratio",
            emp_shares.iter().any(|&s| (s - emp_shares[rungs - 1]).abs() > 0.005),
            format!("big share by rung: {emp_shares:?}"),
        ),
        Assertion::check(
            "empirically weighted online retuning beats its own stale boot split",
            ramp_stats[1].gflops > emp_boot.gflops * 1.01 && ramp_stats[1].retunes > 0,
            format!(
                "online {:.3} vs boot {:.3} GFLOPS ({} retunes)",
                ramp_stats[1].gflops, emp_boot.gflops, ramp_stats[1].retunes
            ),
        ),
    ];

    FigureResult {
        id: "calibrate",
        title: "Calibration layer: measured rates vs the analytical model, and where the weights land",
        tables: vec![rates, weights, casas, per_opp, dvfs],
        assertions,
    }
}

#[cfg(test)]
mod tests {
    #[test]
    fn calibrate_report_passes_quick() {
        let fig = super::run(true);
        assert!(fig.passed(), "{}", fig.to_markdown());
        assert_eq!(fig.tables.len(), 5);
        assert_eq!(fig.id, "calibrate");
    }
}
