//! Fig. 11: CA-SAS at ratio 5 with the four coarse×fine combinations
//! (Loop 1/Loop 3 × Loop 4/Loop 5). Paper findings (§5.3.1): fine-grain
//! Loop 4 tracks the ideal much closer than Loop 5; under Loop 4 the
//! choice of coarse loop is indistinguishable, while under Loop 5 the
//! difference shows (Loop 3 forces the shared-kc refit on the A7).

use crate::figures::{ideal_gflops, sim_square, sizes, Assertion, FigureResult};
use crate::model::PerfModel;
use crate::sched::{CoarseLoop, FineLoop, ScheduleSpec, Strategy, Weights};
use crate::util::table::Table;

pub fn run(model: &PerfModel, quick: bool) -> FigureResult {
    let rs = sizes(quick);
    let combos = [
        (CoarseLoop::Loop1, FineLoop::Loop4),
        (CoarseLoop::Loop3, FineLoop::Loop4),
        (CoarseLoop::Loop1, FineLoop::Loop5),
        (CoarseLoop::Loop3, FineLoop::Loop5),
    ];
    let mut cols = vec!["r".to_string()];
    cols.extend(combos.iter().map(|(c, f)| format!("{}+{}", c.name(), f.name())));
    cols.push("Ideal".into());
    let col_refs: Vec<&str> = cols.iter().map(|s| s.as_str()).collect();
    let mut perf = Table::new("Fig11 CA-SAS(r=5) loop combinations, performance [GFLOPS]", &col_refs);
    let mut eff = Table::new("Fig11 CA-SAS(r=5) loop combinations, energy [GFLOPS/W]", &col_refs);

    let r_max = *rs.last().unwrap();
    let mut at_max = [0.0f64; 4];
    for &r in &rs {
        let mut prow = vec![r as f64];
        let mut erow = vec![r as f64];
        for (i, &(coarse, fine)) in combos.iter().enumerate() {
            let spec = ScheduleSpec::new(
                Strategy::CaSas { weights: Weights::ratio(5.0) },
                coarse,
                fine,
            );
            let st = sim_square(model, &spec, r);
            prow.push(st.gflops);
            erow.push(st.gflops_per_watt);
            if r == r_max {
                at_max[i] = st.gflops;
            }
        }
        prow.push(ideal_gflops(model, r));
        erow.push(f64::NAN);
        perf.push_f64_row(&prow, 3);
        eff.push_f64_row(&erow, 3);
    }

    let ideal = ideal_gflops(model, r_max);
    let assertions = vec![
        Assertion::check(
            "Loop-4 fine grain tracks the ideal closer than Loop 5",
            at_max[0] > at_max[2] && at_max[1] > at_max[3],
            format!(
                "L4: {:.2}/{:.2} vs L5: {:.2}/{:.2}",
                at_max[0], at_max[1], at_max[2], at_max[3]
            ),
        ),
        Assertion::check(
            "under Loop 4, coarse L1 ≈ coarse L3 (§5.3.1)",
            (at_max[0] / at_max[1] - 1.0).abs() < 0.05,
            format!("L1+L4 {:.2} vs L3+L4 {:.2}", at_max[0], at_max[1]),
        ),
        Assertion::check(
            "under Loop 5, the coarse-loop choice matters",
            (at_max[2] / at_max[3] - 1.0).abs()
                > (at_max[0] / at_max[1] - 1.0).abs(),
            format!("L5 gap {:.3} vs L4 gap {:.3}",
                (at_max[2] / at_max[3] - 1.0).abs(),
                (at_max[0] / at_max[1] - 1.0).abs()),
        ),
        Assertion::check(
            "best combination approaches the ideal",
            at_max[0].max(at_max[1]) > 0.90 * ideal,
            format!("best {:.2} vs ideal {:.2}", at_max[0].max(at_max[1]), ideal),
        ),
    ];

    FigureResult {
        id: "fig11",
        title: "CA-SAS(r=5): coarse Loop 1/3 × fine Loop 4/5",
        tables: vec![perf, eff],
        assertions,
    }
}
