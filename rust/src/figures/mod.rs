//! Regeneration harness for every evaluation figure in the paper.
//!
//! The paper's evaluation is entirely figures (no numeric tables):
//! Fig. 4 (cache-parameter search), Fig. 5 (isolated clusters), Fig. 7
//! (architecture-oblivious SSS), Fig. 9 (SAS ratio sweep), Fig. 10
//! (SAS vs CA-SAS), Fig. 11 (CA-SAS loop combinations), Fig. 12
//! (dynamic CA-DAS/DAS). Figures 1–3, 6 and 8 are diagrams, not data.
//!
//! Each module produces the figure's data series as [`Table`]s (CSV +
//! markdown) plus *shape assertions* — machine-checked statements of the
//! qualitative claims the paper draws from that figure (who wins, where
//! the crossover sits, by roughly what factor). `cargo test` runs all of
//! them in quick mode; `amp-gemm figures` and `cargo bench` regenerate
//! the full versions. DESIGN.md §9 indexes every experiment.
//!
//! Beyond the paper: [`ablation`] covers the §6 future-work knobs,
//! [`fleet`] is the multi-board throughput-scaling report
//! (`amp-gemm fleet --report`), [`dvfs`] is the operating-point
//! Pareto-frontier / online-retuning report (`amp-gemm dvfs --report`)
//! [`calibrate`] is the measured-rate weight-calibration report
//! (`amp-gemm calibrate --report`), [`live`] is the online-calibration
//! convergence report (`amp-gemm calibrate --live`), [`autoscale`]
//! is the SLO-driven elastic-fleet / closed-loop-governor report
//! (`amp-gemm autoscale`) and [`dag`] is the task-DAG factorization /
//! unified-job-API report (`amp-gemm dag --report`).

pub mod ablation;
pub mod autoscale;
pub mod calibrate;
pub mod dag;
pub mod dvfs;
pub mod fig10;
pub mod fleet;
pub mod fig11;
pub mod live;
pub mod fig12;
pub mod fig4;
pub mod fig5;
pub mod fig7;
pub mod fig9;

use crate::model::PerfModel;
use crate::sched::ScheduleSpec;
use crate::sim::{simulate, RunStats};
use crate::util::table::Table;
use std::io;
use std::path::Path;

/// One machine-checked qualitative claim from a figure.
#[derive(Debug, Clone)]
pub struct Assertion {
    pub name: String,
    pub pass: bool,
    pub detail: String,
}

impl Assertion {
    pub fn check(name: &str, pass: bool, detail: String) -> Self {
        Assertion {
            name: name.to_string(),
            pass,
            detail,
        }
    }
}

/// A regenerated figure: its data tables plus shape assertions.
#[derive(Debug, Clone)]
pub struct FigureResult {
    pub id: &'static str,
    pub title: &'static str,
    pub tables: Vec<Table>,
    pub assertions: Vec<Assertion>,
}

impl FigureResult {
    pub fn passed(&self) -> bool {
        self.assertions.iter().all(|a| a.pass)
    }

    /// Write every table as `<dir>/<id>_<n>.csv`.
    pub fn write_csvs(&self, dir: &Path) -> io::Result<Vec<std::path::PathBuf>> {
        let mut paths = Vec::new();
        for (i, t) in self.tables.iter().enumerate() {
            let path = dir.join(format!("{}_{}.csv", self.id, i));
            t.write_csv(&path)?;
            paths.push(path);
        }
        Ok(paths)
    }

    pub fn to_markdown(&self) -> String {
        let mut out = format!("## {} — {}\n\n", self.id, self.title);
        for t in &self.tables {
            out.push_str(&t.to_markdown());
            out.push('\n');
        }
        out.push_str("**Shape assertions**\n\n");
        for a in &self.assertions {
            out.push_str(&format!(
                "- [{}] {}: {}\n",
                if a.pass { "PASS" } else { "FAIL" },
                a.name,
                a.detail
            ));
        }
        out
    }
}

/// Problem sizes (square, r = m = n = k, double precision as in §3.2).
pub fn sizes(quick: bool) -> Vec<usize> {
    if quick {
        vec![512, 1024, 2048, 4096]
    } else {
        vec![256, 512, 768, 1024, 1536, 2048, 2560, 3072, 4096, 5120, 6144]
    }
}

/// Convenience wrapper: simulate a square problem.
pub fn sim_square(model: &PerfModel, spec: &ScheduleSpec, r: usize) -> RunStats {
    simulate(model, spec, crate::blis::gemm::GemmShape::square(r))
}

/// The "Ideal" line of Fig. 7/9/10/11/12: the aggregated performance of
/// every isolated cluster at the same problem size (two clusters on the
/// Exynos; N terms on an N-cluster topology).
pub fn ideal_gflops(model: &PerfModel, r: usize) -> f64 {
    model
        .soc
        .cluster_ids()
        .map(|c| {
            sim_square(
                model,
                &ScheduleSpec::cluster_only(c, model.soc[c].num_cores),
                r,
            )
            .gflops
        })
        .sum()
}

/// Run one figure by number (4, 5, 7, 9, 10, 11, 12).
pub fn run_figure(id: usize, model: &PerfModel, quick: bool) -> Option<FigureResult> {
    match id {
        4 => Some(fig4::run(model)),
        5 => Some(fig5::run(model, quick)),
        7 => Some(fig7::run(model, quick)),
        9 => Some(fig9::run(model, quick)),
        10 => Some(fig10::run(model, quick)),
        11 => Some(fig11::run(model, quick)),
        12 => Some(fig12::run(model, quick)),
        _ => None,
    }
}

/// All figure ids with data content.
pub const FIGURE_IDS: [usize; 7] = [4, 5, 7, 9, 10, 11, 12];

/// Run the complete evaluation.
pub fn run_all(model: &PerfModel, quick: bool) -> Vec<FigureResult> {
    FIGURE_IDS
        .iter()
        .map(|&id| run_figure(id, model, quick).unwrap())
        .collect()
}

/// Shared entry point for the per-figure bench binaries
/// (`cargo bench --bench figN`): regenerate the figure in full mode,
/// time the regeneration, print the data series + shape assertions and
/// write the CSVs. Exits non-zero if any assertion fails so `make bench`
/// doubles as a reproduction gate.
pub fn bench_figure_main(id: usize) {
    let model = PerfModel::exynos();
    let mut b = crate::util::benchkit::Bencher::quick();
    let mut result: Option<FigureResult> = None;
    b.bench(&format!("fig{id} regeneration (full sweep)"), || {
        result = run_figure(id, &model, false);
    });
    let fig = result.expect("known figure id");
    println!("{}", fig.to_markdown());
    b.report(&format!("fig{id} bench"));
    let out = Path::new("results");
    let paths = fig.write_csvs(out).expect("write csvs");
    println!("\nwrote {} CSVs under results/", paths.len());
    if !fig.passed() {
        eprintln!("FAIL: shape assertions did not hold");
        std::process::exit(1);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn all_figures_regenerate_and_pass_quick() {
        let model = PerfModel::exynos();
        for fig in run_all(&model, true) {
            assert!(
                fig.passed(),
                "{} failed assertions:\n{}",
                fig.id,
                fig.to_markdown()
            );
            assert!(!fig.tables.is_empty());
        }
    }

    #[test]
    fn unknown_figure_is_none() {
        assert!(run_figure(6, &PerfModel::exynos(), true).is_none());
    }

    #[test]
    fn csv_export_works() {
        let model = PerfModel::exynos();
        let fig = run_figure(9, &model, true).unwrap();
        let dir = std::env::temp_dir().join("amp_gemm_figtest");
        let _ = std::fs::remove_dir_all(&dir);
        let paths = fig.write_csvs(&dir).unwrap();
        assert!(!paths.is_empty());
        assert!(paths.iter().all(|p| p.exists()));
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn ideal_is_above_each_cluster() {
        let model = PerfModel::exynos();
        let ideal = ideal_gflops(&model, 2048);
        let big = sim_square(&model, &ScheduleSpec::cluster_only(crate::soc::BIG, 4), 2048);
        assert!(ideal > big.gflops);
    }
}
