//! Fig. 4: BLIS optimal cache configuration parameters (mc, kc) for the
//! Cortex-A15 and Cortex-A7 — coarse heatmap + fine refinement, optima
//! marked. Paper optima: A15 (152, 952), A7 (80, 352).

use crate::figures::{Assertion, FigureResult};
use crate::model::PerfModel;
use crate::search::{shared_kc_refit, two_phase_search};
use crate::soc::{BIG, LITTLE};

pub fn run(model: &PerfModel) -> FigureResult {
    let mut tables = Vec::new();
    let mut assertions = Vec::new();

    let (coarse_big, fine_big) = two_phase_search(model, BIG);
    let (coarse_little, fine_little) = two_phase_search(model, LITTLE);

    tables.push(coarse_big.to_table("Fig4 A15 coarse (mc,kc) sweep [GFLOPS]"));
    tables.push(fine_big.to_table("Fig4 A15 fine sweep"));
    tables.push(coarse_little.to_table("Fig4 A7 coarse (mc,kc) sweep [GFLOPS]"));
    tables.push(fine_little.to_table("Fig4 A7 fine sweep"));

    let b = fine_big.best;
    assertions.push(Assertion::check(
        "A15 optimum near paper (152, 952)",
        (136..=168).contains(&b.mc) && (888..=1000).contains(&b.kc),
        format!("found ({}, {}) @ {:.2} GFLOPS; paper (152, 952)", b.mc, b.kc, b.gflops),
    ));
    assertions.push(Assertion::check(
        "A15 single-core rate ≈ 2.8–3.0 GFLOPS",
        (2.7..3.0).contains(&b.gflops),
        format!("{:.3} GFLOPS", b.gflops),
    ));

    let l = fine_little.best;
    assertions.push(Assertion::check(
        "A7 optimum near paper (80, 352)",
        (64..=96).contains(&l.mc) && (320..=390).contains(&l.kc),
        format!("found ({}, {}) @ {:.2} GFLOPS; paper (80, 352)", l.mc, l.kc, l.gflops),
    ));
    assertions.push(Assertion::check(
        "A15 optimal (mc, kc) larger than A7's (4× L2)",
        b.mc > l.mc && b.kc > l.kc,
        format!("A15 ({}, {}) vs A7 ({}, {})", b.mc, b.kc, l.mc, l.kc),
    ));

    // §5.3 constrained refit (reported in the text, derived from the
    // same search machinery): kc pinned to 952 → A7 mc ≈ 32.
    let refit = shared_kc_refit(model, LITTLE, 952);
    tables.push(refit.to_table("§5.3 A7 refit at shared kc=952"));
    assertions.push(Assertion::check(
        "A7 shared-kc refit mc ≈ 32",
        (24..=40).contains(&refit.best.mc),
        format!("found mc = {}; paper 32", refit.best.mc),
    ));

    FigureResult {
        id: "fig4",
        title: "Optimal cache configuration parameters (mc, kc) per core type",
        tables,
        assertions,
    }
}
