//! Fig. 5: performance (GFLOPS) and energy efficiency (GFLOPS/W) of the
//! BLIS GEMM using exclusively one type of core, 1–4 threads, across
//! problem sizes. Paper anchors (§3.4): A15 ≈ +2.8 GFLOPS/core up to 3
//! cores, smaller 4th-core increment, cluster peak ≈ 9.6; A7 peak ≈ 2.4;
//! best A15 efficiency with 3 cores; full-A7 efficiency ≈ 2× single-A7.

use crate::figures::{sim_square, sizes, Assertion, FigureResult};
use crate::model::PerfModel;
use crate::sched::ScheduleSpec;
use crate::soc::{BIG, LITTLE};
use crate::util::table::Table;

pub fn run(model: &PerfModel, quick: bool) -> FigureResult {
    let rs = sizes(quick);
    let mut perf = Table::new(
        "Fig5 performance: isolated clusters, 1–4 threads [GFLOPS]",
        &["r", "A15x1", "A15x2", "A15x3", "A15x4", "A7x1", "A7x2", "A7x3", "A7x4"],
    );
    let mut eff = Table::new(
        "Fig5 energy efficiency [GFLOPS/W, whole SoC]",
        &["r", "A15x1", "A15x2", "A15x3", "A15x4", "A7x1", "A7x2", "A7x3", "A7x4"],
    );

    let mut peak_perf = vec![0.0f64; 8];
    let mut peak_eff = vec![0.0f64; 8];
    for &r in &rs {
        let mut prow = vec![r as f64];
        let mut erow = vec![r as f64];
        for (idx, (cluster, t)) in [BIG, LITTLE]
            .iter()
            .flat_map(|&c| (1..=4).map(move |t| (c, t)))
            .enumerate()
        {
            let st = sim_square(model, &ScheduleSpec::cluster_only(cluster, t), r);
            prow.push(st.gflops);
            erow.push(st.gflops_per_watt);
            peak_perf[idx] = peak_perf[idx].max(st.gflops);
            peak_eff[idx] = peak_eff[idx].max(st.gflops_per_watt);
        }
        perf.push_f64_row(&prow, 3);
        eff.push_f64_row(&erow, 3);
    }

    let mut assertions = Vec::new();
    assertions.push(Assertion::check(
        "A15 cluster peak ≈ 9.6 GFLOPS",
        (9.0..10.1).contains(&peak_perf[3]),
        format!("{:.2} GFLOPS (paper 9.6)", peak_perf[3]),
    ));
    assertions.push(Assertion::check(
        "A7 cluster peak ≈ 2.4 GFLOPS",
        (2.1..2.6).contains(&peak_perf[7]),
        format!("{:.2} GFLOPS (paper ≈2.4)", peak_perf[7]),
    ));
    let inc3 = peak_perf[2] - peak_perf[1];
    let inc4 = peak_perf[3] - peak_perf[2];
    assertions.push(Assertion::check(
        "4th A15 core adds much less than the 3rd",
        inc4 < 0.65 * inc3,
        format!("3rd +{inc3:.2}, 4th +{inc4:.2} (paper +2.8 vs +1.4)"),
    ));
    assertions.push(Assertion::check(
        "best A15 efficiency at 3 cores",
        peak_eff[2] > peak_eff[3] && peak_eff[2] > peak_eff[1] && peak_eff[2] > peak_eff[0],
        format!(
            "A15 eff by threads: {:.3} {:.3} {:.3} {:.3}",
            peak_eff[0], peak_eff[1], peak_eff[2], peak_eff[3]
        ),
    ));
    assertions.push(Assertion::check(
        "full-A7 efficiency ≈ 2× single-A7",
        (1.6..2.7).contains(&(peak_eff[7] / peak_eff[4])),
        format!("ratio {:.2} (paper ≈2×)", peak_eff[7] / peak_eff[4]),
    ));
    assertions.push(Assertion::check(
        "4×A7 more energy-efficient than 1×A15, though slower",
        peak_eff[7] > peak_eff[0] && peak_perf[7] < peak_perf[0],
        format!(
            "eff {:.3} vs {:.3}; perf {:.2} vs {:.2}",
            peak_eff[7], peak_eff[0], peak_perf[7], peak_perf[0]
        ),
    ));
    assertions.push(Assertion::check(
        "full clusters have similar efficiency (§3.4)",
        (peak_eff[7] / peak_eff[3] - 1.0).abs() < 0.20,
        format!("full-A7 {:.3} vs full-A15 {:.3}", peak_eff[7], peak_eff[3]),
    ));

    FigureResult {
        id: "fig5",
        title: "Isolated-cluster performance and energy efficiency vs threads",
        tables: vec![perf, eff],
        assertions,
    }
}
