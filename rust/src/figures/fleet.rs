//! Fleet-throughput-scaling report (no paper counterpart — the §6
//! "scale-out" roadmap item): the intra-SoC evaluation story retold at
//! the board level.
//!
//! Three tables:
//! 1. board-level strategy comparison on a heterogeneous two-board
//!    fleet — fleet-SSS (equal shards) vs fleet-SAS (throughput-
//!    weighted) vs fleet-DAS (dynamic queue), with per-board shares;
//! 2. homogeneous scaling — sustained req/s for 1–4 Exynos boards
//!    under fleet-DAS;
//! 3. capacity planning — boards needed to sustain rising request-rate
//!    targets.
//!
//! Shape assertions mirror the paper's Figs. 7/9/12 one level up: the
//! oblivious equal split loses to both throughput-aware strategies on a
//! skewed fleet, and scaling is near-linear (boards share nothing but
//! the dispatcher).
//!
//! Table 4 (ISSUE 4) retells the paper's static-vs-dynamic story at the
//! *admission* level: staggered Poisson-like arrivals on the pinned
//! exynos5422 + juno_r0 pair, replayed under today's synchronous
//! wave-per-batch discipline (all three board strategies) and under the
//! streaming dispatcher. Streaming must never lose on makespan and must
//! strictly raise aggregate board utilization — continuous admission is
//! to waves what DAS is to SSS.

use crate::blis::gemm::GemmShape;
use crate::coordinator::MAX_GROUP_LEN;
use crate::figures::{Assertion, FigureResult};
use crate::fleet::sim::{
    boards_to_sustain, poisson_arrivals, simulate_fleet_cached, simulate_fleet_stream_cached,
    simulate_fleet_waves_cached, Arrival, StreamStats,
};
use crate::fleet::{Board, Fleet, FleetStrategy};
use crate::sim::RunCache;
use crate::util::rng::Rng;
use crate::util::table::Table;

/// The pinned two-board streaming fleet (exynos5422 + juno_r0), shared
/// by the report, `examples/stream_sweep.rs` and the golden regression
/// test (`tests/fleet_golden.rs`).
pub fn pinned_stream_fleet() -> Fleet {
    Fleet::parse("exynos5422,juno_r0").expect("presets")
}

/// Staggered Poisson-like arrivals for the streaming section: three
/// mixed shapes at an arrival rate near the pair's service capacity,
/// so wave barriers surface as queueing delay. Deterministic (seeded
/// [`Rng`]); `quick` halves the stream length.
pub fn pinned_stream_arrivals(quick: bool) -> Vec<Arrival> {
    let shapes = [
        GemmShape::square(384),
        GemmShape::square(512),
        GemmShape::square(640),
    ];
    let count = if quick { 24 } else { 48 };
    let mut rng = Rng::new(0x5EED_57);
    poisson_arrivals(&mut rng, &shapes, count, 80.0)
}

/// One rendered row of the streaming table. Public so the golden test
/// pins the exact formatting alongside the numbers.
pub fn stream_row(st: &StreamStats) -> Vec<String> {
    vec![
        st.label.clone(),
        format!("{:.3}", st.makespan_s),
        format!("{:.2}", st.throughput_rps),
        format!("{:.3}", st.utilization),
        format!("{:.3}", st.sojourn_p50_s),
        format!("{:.3}", st.sojourn_p99_s),
        format!("{:.2}", st.mean_queue_depth),
        st.max_queue_depth.to_string(),
        format!("{:.1}", st.energy_j),
    ]
}

/// Columns of the streaming-vs-wave comparison, shared by every
/// renderer (report, `amp-gemm fleet --stream`, the example). The
/// p50/p99 sojourn percentiles (completion − arrival, submission-
/// indexed) close the ROADMAP "latency percentiles in the streaming
/// report" follow-on.
pub const STREAM_COLUMNS: &[&str] = &[
    "mode",
    "makespan [s]",
    "req/s",
    "utilization",
    "p50 [s]",
    "p99 [s]",
    "mean depth",
    "max depth",
    "energy [J]",
];

/// The streaming-vs-wave comparison on any fleet and arrival stream:
/// one row per wave-mode strategy plus the streaming dispatcher.
/// Returns the table with the three wave replays and the stream replay
/// for assertions — the single implementation behind the report, the
/// CLI and `examples/stream_sweep.rs`. All four replays share one
/// `RunCache`, so every distinct (board config, shape) pair prices one
/// DES run for the whole table (the numbers are bit-identical either
/// way — pinned by `tests/fleet_golden.rs`).
pub fn stream_table(
    title: &str,
    fleet: &Fleet,
    arrivals: &[Arrival],
) -> (Table, Vec<StreamStats>, StreamStats) {
    let mut table = Table::new(title, STREAM_COLUMNS);
    let mut waves = Vec::new();
    let mut cache = RunCache::new();
    for strategy in [FleetStrategy::Sss, FleetStrategy::Sas, FleetStrategy::Das] {
        let st = simulate_fleet_waves_cached(fleet, strategy, arrivals, MAX_GROUP_LEN, &mut cache);
        table.push_row(stream_row(&st));
        waves.push(st);
    }
    let stream = simulate_fleet_stream_cached(fleet, arrivals, &mut cache);
    table.push_row(stream_row(&stream));
    (table, waves, stream)
}

/// [`stream_table`] on the pinned scenario — the report's table 4 and
/// the golden test's subject.
pub fn stream_section(quick: bool) -> (Table, Vec<StreamStats>, StreamStats) {
    let fleet = pinned_stream_fleet();
    let arrivals = pinned_stream_arrivals(quick);
    stream_table(
        &format!(
            "Streaming vs wave dispatch — exynos5422 + juno_r0, {} staggered arrivals",
            arrivals.len()
        ),
        &fleet,
        &arrivals,
    )
}

pub fn run(quick: bool) -> FigureResult {
    let r = if quick { 1024 } else { 2048 };
    let batch = if quick { 32 } else { 64 };
    let shape = GemmShape::square(r);

    // --- Table 1: strategies on a skewed heterogeneous fleet. ---
    let fleet = Fleet::parse("exynos5422,dynamiq_3c").expect("presets");
    let mut cmp = Table::new(
        &format!(
            "Fleet strategies — exynos5422 + dynamiq_3c, r = {r}, batch = {batch}"
        ),
        &["strategy", "makespan [s]", "GFLOPS", "req/s", "energy [J]", "GFLOPS/W", "items/board"],
    );
    let mut by_strategy = Vec::new();
    let mut cache = RunCache::new();
    for strategy in [FleetStrategy::Sss, FleetStrategy::Sas, FleetStrategy::Das] {
        let st = simulate_fleet_cached(&fleet, strategy, shape, batch, &mut cache);
        cmp.push_row(vec![
            strategy.label().to_string(),
            format!("{:.3}", st.makespan_s),
            format!("{:.2}", st.gflops),
            format!("{:.2}", st.throughput_rps),
            format!("{:.1}", st.energy_j),
            format!("{:.3}", st.gflops_per_watt),
            st.boards
                .iter()
                .map(|b| b.items.to_string())
                .collect::<Vec<_>>()
                .join("+"),
        ]);
        by_strategy.push(st);
    }
    let (sss, sas, das) = (&by_strategy[0], &by_strategy[1], &by_strategy[2]);

    // --- Table 2: homogeneous fleet-DAS scaling. ---
    let exynos = Board::from_preset("exynos5422").expect("preset");
    let mut scaling = Table::new(
        &format!("Fleet-DAS scaling — n × exynos5422, r = {r}, batch = {batch}"),
        &["boards", "req/s", "speedup", "GFLOPS", "GFLOPS/W"],
    );
    let mut rps = Vec::new();
    for n in 1..=4 {
        let hom = Fleet::homogeneous(n, &exynos);
        let st = simulate_fleet_cached(&hom, FleetStrategy::Das, shape, batch, &mut cache);
        rps.push(st.throughput_rps);
        scaling.push_row(vec![
            n.to_string(),
            format!("{:.2}", st.throughput_rps),
            format!("{:.2}x", st.throughput_rps / rps[0]),
            format!("{:.2}", st.gflops),
            format!("{:.3}", st.gflops_per_watt),
        ]);
    }

    // --- Table 3: capacity planning against rising rate targets. ---
    let mut capacity = Table::new(
        "Capacity planning — Exynos boards to sustain a target req/s",
        &["target [req/s]", "boards"],
    );
    let mut plan = Vec::new();
    for mult in [0.5, 1.5, 2.5, 3.5] {
        let target = mult * rps[0];
        let need = boards_to_sustain(&exynos, shape, batch, target, 8);
        capacity.push_row(vec![
            format!("{target:.2}"),
            need.map_or("> 8".to_string(), |n| n.to_string()),
        ]);
        plan.push(need);
    }

    // --- Table 4: streaming vs wave dispatch on staggered arrivals. ---
    let (streaming, wave_stats, stream) = stream_section(quick);

    let mut assertions = vec![
        Assertion::check(
            "fleet-DAS beats equal-shard fleet-SSS on a heterogeneous fleet",
            das.makespan_s < 0.90 * sss.makespan_s,
            format!("DAS {:.3}s vs SSS {:.3}s", das.makespan_s, sss.makespan_s),
        ),
        Assertion::check(
            "throughput-weighted fleet-SAS also beats fleet-SSS",
            sas.makespan_s < 0.95 * sss.makespan_s,
            format!("SAS {:.3}s vs SSS {:.3}s", sas.makespan_s, sss.makespan_s),
        ),
        Assertion::check(
            "dynamic tracks the weighted-static optimum",
            (sas.makespan_s / das.makespan_s - 1.0).abs() < 0.20,
            format!("SAS {:.3}s vs DAS {:.3}s", sas.makespan_s, das.makespan_s),
        ),
        Assertion::check(
            "balanced shards also win on energy (idle boards burn rails)",
            das.gflops_per_watt > sss.gflops_per_watt,
            format!("DAS {:.3} vs SSS {:.3} GFLOPS/W", das.gflops_per_watt, sss.gflops_per_watt),
        ),
        Assertion::check(
            "every strategy completes the whole batch",
            by_strategy.iter().all(|st| st.items_completed() == batch),
            format!(
                "completed {:?}",
                by_strategy.iter().map(|st| st.items_completed()).collect::<Vec<_>>()
            ),
        ),
        Assertion::check(
            "homogeneous scaling is monotone and near-linear",
            rps.windows(2).all(|w| w[1] > w[0]) && rps[3] > 3.0 * rps[0],
            format!("req/s by boards: {rps:?}"),
        ),
        Assertion::check(
            "capacity plan grows with the rate target",
            plan[0] == Some(1)
                && plan
                    .windows(2)
                    .all(|w| w[1].unwrap_or(9) >= w[0].unwrap_or(9)),
            format!("boards needed: {plan:?}"),
        ),
    ];

    // ISSUE 4 acceptance: continuous admission never loses on makespan
    // and strictly raises aggregate utilization over every wave mode.
    assertions.push(Assertion::check(
        "streaming makespan never exceeds any wave mode's",
        wave_stats.iter().all(|w| stream.makespan_s <= w.makespan_s),
        format!(
            "stream {:.3}s vs waves {:?}",
            stream.makespan_s,
            wave_stats.iter().map(|w| w.makespan_s).collect::<Vec<_>>()
        ),
    ));
    assertions.push(Assertion::check(
        "streaming strictly raises aggregate board utilization",
        wave_stats.iter().all(|w| stream.utilization > w.utilization),
        format!(
            "stream {:.3} vs waves {:?}",
            stream.utilization,
            wave_stats.iter().map(|w| w.utilization).collect::<Vec<_>>()
        ),
    ));
    assertions.push(Assertion::check(
        "sojourn percentiles are well-formed (0 < p50 <= p99 <= makespan)",
        {
            let ok = |st: &StreamStats| {
                st.sojourn_p50_s > 0.0
                    && st.sojourn_p50_s <= st.sojourn_p99_s
                    && st.sojourn_p99_s <= st.makespan_s + 1e-12
            };
            ok(&stream) && wave_stats.iter().all(ok)
        },
        format!(
            "stream p50/p99 {:.3}/{:.3}s, waves {:?}",
            stream.sojourn_p50_s,
            stream.sojourn_p99_s,
            wave_stats
                .iter()
                .map(|w| (w.sojourn_p50_s, w.sojourn_p99_s))
                .collect::<Vec<_>>()
        ),
    ));
    assertions.push(Assertion::check(
        "streaming executes every request exactly once, merged in submission order",
        stream.items_completed() == stream.requests
            && stream.completions.iter().all(|c| c.is_finite())
            && stream
                .per_job
                .iter()
                .map(|(_, c)| c)
                .sum::<usize>()
                == stream.requests
            && wave_stats.iter().all(|w| w.items_completed() == w.requests),
        format!(
            "stream {}/{} requests, per job {:?}",
            stream.items_completed(),
            stream.requests,
            stream.per_job
        ),
    ));

    FigureResult {
        id: "fleet",
        title: "Fleet scale-out: board-level SSS/SAS/DAS and throughput scaling",
        tables: vec![cmp, scaling, capacity, streaming],
        assertions,
    }
}

#[cfg(test)]
mod tests {
    #[test]
    fn fleet_report_passes_quick() {
        let fig = super::run(true);
        assert!(fig.passed(), "{}", fig.to_markdown());
        assert_eq!(fig.tables.len(), 4);
        assert_eq!(fig.id, "fleet");
    }

    /// The pinned streaming scenario is stable: same fleet, same seed,
    /// same arrivals — the precondition of the golden regression test.
    #[test]
    fn pinned_stream_scenario_is_deterministic() {
        let a = super::pinned_stream_arrivals(true);
        let b = super::pinned_stream_arrivals(true);
        assert_eq!(a, b);
        assert_eq!(a.len(), 24);
        assert_eq!(super::pinned_stream_arrivals(false).len(), 48);
        assert_eq!(super::pinned_stream_fleet().num_boards(), 2);
    }
}
