//! SLO autoscaling + closed-loop governor report (ISSUE 8; ROADMAP
//! open item #1 — `amp-gemm autoscale`).
//!
//! Three tables:
//! 1. **rate sweep past saturation** — pinned Poisson streams at rising
//!    multiples of one board's sustained throughput, each planned by the
//!    [`Autoscaler`] against a p99-sojourn SLO, next to the *static*
//!    fleet sized once for the sweep's peak. The acceptance claim is
//!    aggregate: the autoscaler holds the SLO at every rate and the
//!    sweep's total provisioned cost is strictly below parking the
//!    peak-sized fleet at every rate;
//! 2. **heterogeneous downgrade** — at a mid rate, a catalog with a
//!    cheaper template must never cost more than the smallest
//!    homogeneous reference fleet that holds the same SLO;
//! 3. **closed-loop vs open-loop ondemand** — the load-driven governor
//!    ([`plan_load_driven`] at the SoC level,
//!    [`plan_fleet_dvfs_load_driven`] at the board level) must match the
//!    blind time-ramp's makespan while strictly cutting energy: the
//!    feedback only steps down rungs the ramp was burning on idle tails.

use crate::blis::gemm::GemmShape;
use crate::calibrate::WeightSource;
use crate::dvfs::sim::{simulate_dvfs, simulate_dvfs_load_driven, DvfsStrategy, Retune};
use crate::dvfs::{Governor, Ondemand};
use crate::figures::{Assertion, FigureResult};
use crate::fleet::autoscale::{AutoscaleDecision, Autoscaler, SloPolicy};
use crate::fleet::sim::{
    poisson_arrivals, simulate_fleet, simulate_fleet_dvfs_cached,
    simulate_fleet_dvfs_load_driven, simulate_fleet_stream_cached, Arrival,
};
use crate::fleet::{Board, Fleet, FleetStrategy};
use crate::sim::RunCache;
use crate::soc::SocSpec;
use crate::util::rng::Rng;
use crate::util::table::Table;

/// Rate multiples (× one board's sustained req/s) the sweep visits —
/// from comfortable headroom to well past single-board saturation.
pub const RATE_MULTS: [f64; 4] = [0.5, 1.2, 2.0, 3.0];

/// The pinned sweep scenario: streams, SLO and reference board shared
/// by the report and the perf-trajectory gate, so the CI rows pin the
/// exact decisions the figure asserts on.
#[derive(Debug)]
pub struct SweepScenario {
    pub template: Board,
    pub shape: GemmShape,
    pub slo: SloPolicy,
    /// One Poisson stream per entry of [`RATE_MULTS`], deterministic.
    pub streams: Vec<Vec<Arrival>>,
    /// The rates the streams were drawn at, req/s.
    pub rates: Vec<f64>,
}

/// Build the pinned sweep: `count` requests per stream (the trajectory
/// gate and quick mode use 40, the full report 80).
pub fn sweep_scenario(count: usize) -> SweepScenario {
    let template = Board::from_preset("exynos5422").expect("preset");
    let shape = GemmShape::square(1024);
    let solo = simulate_fleet(
        &Fleet::homogeneous(1, &template),
        FleetStrategy::Das,
        shape,
        16,
    )
    .throughput_rps;
    let item = crate::sim::simulate(template.model(), &template.sched, shape).time_s;
    let slo = SloPolicy::new(12.0 * item);
    let mut streams = Vec::new();
    let mut rates = Vec::new();
    for (i, mult) in RATE_MULTS.iter().enumerate() {
        let rate = mult * solo;
        let mut rng = Rng::new(0xA5CA + i as u64);
        streams.push(poisson_arrivals(&mut rng, &[shape], count, rate));
        rates.push(rate);
    }
    SweepScenario { template, shape, slo, streams, rates }
}

/// Autoscale every stream of the sweep (single-template catalog — the
/// sweep isolates *elasticity*; table 2 covers catalog mixing).
pub fn sweep_decisions(sc: &SweepScenario, cache: &mut RunCache) -> Vec<AutoscaleDecision> {
    let scaler = Autoscaler::new(sc.slo, vec![sc.template.clone()]);
    sc.streams.iter().map(|a| scaler.plan(a, cache)).collect()
}

/// Smallest homogeneous fleet of `template` boards holding `slo` on
/// *every* stream — the static fleet a peak-load capacity plan parks.
pub fn peak_static_boards(sc: &SweepScenario, cache: &mut RunCache) -> Option<usize> {
    'outer: for n in 1..=crate::sched::MAX_WAYS {
        let fleet = Fleet::homogeneous(n, &sc.template);
        for arrivals in &sc.streams {
            let st = simulate_fleet_stream_cached(&fleet, arrivals, cache);
            if !sc.slo.met_by(&st) {
                continue 'outer;
            }
        }
        return Some(n);
    }
    None
}

pub fn run(quick: bool) -> FigureResult {
    let count = if quick { 40 } else { 80 };
    let mut cache = RunCache::new();

    // --- Table 1: the rate sweep, autoscaled vs peak-sized static. ---
    let sc = sweep_scenario(count);
    let decisions = sweep_decisions(&sc, &mut cache);
    let static_n = peak_static_boards(&sc, &mut cache)
        .expect("some static fleet within the rack limit must hold the SLO");
    let static_fleet = Fleet::homogeneous(static_n, &sc.template);
    let static_price = static_fleet.price_per_hour();

    let mut sweep = Table::new(
        &format!(
            "SLO rate sweep — exynos5422 catalog, {} req/stream, p99 SLO {:.3} s",
            count, sc.slo.p99_sojourn_s
        ),
        &[
            "rate [req/s]",
            "x solo",
            "boards",
            "$/h",
            "p99 [s]",
            "SLO",
            "evals",
            "static p99 [s]",
        ],
    );
    let mut static_p99 = Vec::new();
    for (i, d) in decisions.iter().enumerate() {
        let st = simulate_fleet_stream_cached(&static_fleet, &sc.streams[i], &mut cache);
        static_p99.push(st.sojourn_p99_s);
        sweep.push_row(vec![
            format!("{:.2}", sc.rates[i]),
            format!("{:.1}", RATE_MULTS[i]),
            d.fleet.num_boards().to_string(),
            format!("{:.2}", d.price_per_hour),
            format!("{:.3}", d.stats.sojourn_p99_s),
            if d.slo_met { "met" } else { "MISS" }.to_string(),
            d.evaluations.to_string(),
            format!("{:.3}", st.sojourn_p99_s),
        ]);
    }
    let auto_total: f64 = decisions.iter().map(|d| d.price_per_hour).sum();
    let static_total = static_price * RATE_MULTS.len() as f64;
    sweep.push_row(vec![
        "sweep total".to_string(),
        String::new(),
        format!("vs {static_n} static"),
        format!("{auto_total:.2}"),
        String::new(),
        String::new(),
        String::new(),
        format!("static ${static_total:.2}"),
    ]);

    // --- Table 2: heterogeneous downgrade vs homogeneous static. ---
    let little = Board::from_preset("symmetric2").expect("preset");
    let mid_rate = 1.4 * sc.rates[0] / RATE_MULTS[0];
    let mut rng = Rng::new(0xD0C5);
    let mid_arrivals = poisson_arrivals(&mut rng, &[sc.shape], count, mid_rate);
    let hetero = Autoscaler::new(sc.slo, vec![sc.template.clone(), little.clone()]);
    let mix = hetero.plan(&mid_arrivals, &mut cache);
    let mut homog_n = None;
    for n in 1..=crate::sched::MAX_WAYS {
        let st = simulate_fleet_stream_cached(
            &Fleet::homogeneous(n, &sc.template),
            &mid_arrivals,
            &mut cache,
        );
        if sc.slo.met_by(&st) {
            homog_n = Some(n);
            break;
        }
    }
    let homog_n = homog_n.expect("a homogeneous fleet must hold the SLO at the mid rate");
    let homog_fleet = Fleet::homogeneous(homog_n, &sc.template);
    let homog_st = simulate_fleet_stream_cached(&homog_fleet, &mid_arrivals, &mut cache);
    let mut downgrade = Table::new(
        &format!("Heterogeneous downgrade — {mid_rate:.2} req/s, same SLO"),
        &["fleet", "boards", "$/h", "p99 [s]", "SLO"],
    );
    downgrade.push_row(vec![
        format!(
            "autoscaled [{}]",
            mix.fleet.boards.iter().map(|b| b.name.as_str()).collect::<Vec<_>>().join(", ")
        ),
        mix.fleet.num_boards().to_string(),
        format!("{:.2}", mix.price_per_hour),
        format!("{:.3}", mix.stats.sojourn_p99_s),
        if mix.slo_met { "met" } else { "MISS" }.to_string(),
    ]);
    downgrade.push_row(vec![
        format!("static {homog_n} x exynos5422"),
        homog_n.to_string(),
        format!("{:.2}", homog_fleet.price_per_hour()),
        format!("{:.3}", homog_st.sojourn_p99_s),
        if sc.slo.met_by(&homog_st) { "met" } else { "MISS" }.to_string(),
    ]);

    // --- Table 3: closed-loop vs open-loop ondemand energy. ---
    let soc = SocSpec::exynos5422();
    let r = if quick { 2048 } else { 4096 };
    let period = if quick { 0.25 } else { 0.5 };
    let shape = GemmShape::square(r);
    let strat = DvfsStrategy::Sas { cache_aware: true };
    let gov = Ondemand::new(period);
    let open = simulate_dvfs(&soc, strat, shape, &gov.plan(&soc, 1e3), Retune::Boot);
    let (closed, _plan) =
        simulate_dvfs_load_driven(&soc, strat, shape, &gov, Retune::Boot, &WeightSource::Analytical);

    let fgov = Ondemand::new(0.25);
    let fleet = Fleet::parse("exynos5422,dynamiq_3c").expect("presets");
    let fshape = GemmShape::square(1024);
    let fbatch = 24;
    let open_plans: Vec<_> = fleet.boards.iter().map(|b| fgov.plan(b.soc(), 1e3)).collect();
    let fleet_open = simulate_fleet_dvfs_cached(
        &fleet,
        FleetStrategy::Sss,
        fshape,
        fbatch,
        &open_plans,
        &mut cache,
    );
    let (fleet_closed, _plans) = simulate_fleet_dvfs_load_driven(
        &fleet,
        FleetStrategy::Sss,
        fshape,
        fbatch,
        &fgov,
        &mut cache,
    );

    let mut energy = Table::new(
        &format!(
            "Closed-loop vs open-loop ondemand — CA-SAS r = {r} (SoC), \
             fleet-SSS r = 1024 x {fbatch} (boards)"
        ),
        &["mode", "makespan [s]", "energy [J]", "GFLOPS/W"],
    );
    for (label, time_s, energy_j, gpw) in [
        ("SoC time ramp", open.time_s, open.energy_j, open.gflops_per_watt),
        ("SoC load-driven", closed.time_s, closed.energy_j, closed.gflops_per_watt),
        (
            "fleet time ramp",
            fleet_open.makespan_s,
            fleet_open.energy_j,
            fleet_open.gflops_per_watt,
        ),
        (
            "fleet load-driven",
            fleet_closed.makespan_s,
            fleet_closed.energy_j,
            fleet_closed.gflops_per_watt,
        ),
    ] {
        energy.push_row(vec![
            label.to_string(),
            format!("{time_s:.3}"),
            format!("{energy_j:.1}"),
            format!("{gpw:.3}"),
        ]);
    }

    let assertions = vec![
        Assertion::check(
            "the autoscaler holds the p99 SLO at every rate in the sweep",
            decisions.iter().all(|d| d.slo_met),
            format!(
                "p99 by rate: {:?} vs SLO {:.3}s",
                decisions.iter().map(|d| d.stats.sojourn_p99_s).collect::<Vec<_>>(),
                sc.slo.p99_sojourn_s
            ),
        ),
        Assertion::check(
            "provisioning grows past single-board saturation",
            decisions[0].fleet.num_boards() == 1
                && decisions.last().unwrap().fleet.num_boards() > 1
                && decisions
                    .windows(2)
                    .all(|w| w[1].fleet.num_boards() >= w[0].fleet.num_boards()),
            format!(
                "boards by rate: {:?}",
                decisions.iter().map(|d| d.fleet.num_boards()).collect::<Vec<_>>()
            ),
        ),
        Assertion::check(
            "no rate is provisioned above the peak-sized static fleet",
            decisions.iter().all(|d| d.price_per_hour <= static_price + 1e-12),
            format!(
                "$/h by rate: {:?} vs static ${static_price:.2}",
                decisions.iter().map(|d| d.price_per_hour).collect::<Vec<_>>()
            ),
        ),
        // ISSUE 8 acceptance: SLO met at strictly lower cost than the
        // smallest static fleet that also meets it (sweep aggregate —
        // elasticity is the win; the static fleet must pay for the peak
        // at every rate).
        Assertion::check(
            "elastic provisioning is strictly cheaper than the peak-sized static fleet",
            auto_total < static_total,
            format!("${auto_total:.2} autoscaled vs ${static_total:.2} static over the sweep"),
        ),
        Assertion::check(
            "a heterogeneous catalog never costs more than homogeneous static",
            mix.slo_met && mix.price_per_hour <= homog_fleet.price_per_hour() + 1e-12,
            format!(
                "${:.2}/h mixed vs ${:.2}/h for {homog_n} x exynos5422",
                mix.price_per_hour,
                homog_fleet.price_per_hour()
            ),
        ),
        // ISSUE 8 acceptance: load-driven ondemand beats the blind time
        // ramp on energy at equal makespan, at both levels.
        Assertion::check(
            "closed-loop ondemand matches the open-loop ramp's makespan",
            (closed.time_s / open.time_s - 1.0).abs() < 0.01
                && (fleet_closed.makespan_s / fleet_open.makespan_s - 1.0).abs() < 0.01,
            format!(
                "SoC {:.3}s vs {:.3}s, fleet {:.3}s vs {:.3}s",
                closed.time_s, open.time_s, fleet_closed.makespan_s, fleet_open.makespan_s
            ),
        ),
        Assertion::check(
            "the feedback loop strictly cuts energy at equal makespan",
            closed.energy_j < open.energy_j && fleet_closed.energy_j < fleet_open.energy_j,
            format!(
                "SoC {:.1}J vs {:.1}J, fleet {:.1}J vs {:.1}J",
                closed.energy_j, open.energy_j, fleet_closed.energy_j, fleet_open.energy_j
            ),
        ),
    ];

    FigureResult {
        id: "autoscale",
        title: "SLO autoscaling: elastic fleets vs peak static, closed-loop governor energy",
        tables: vec![sweep, downgrade, energy],
        assertions,
    }
}

#[cfg(test)]
mod tests {
    #[test]
    fn autoscale_report_passes_quick() {
        let fig = super::run(true);
        assert!(fig.passed(), "{}", fig.to_markdown());
        assert_eq!(fig.tables.len(), 3);
        assert_eq!(fig.id, "autoscale");
    }

    /// The pinned sweep is deterministic — the precondition of the
    /// trajectory rows reading the same decisions the figure asserts on.
    #[test]
    fn sweep_scenario_is_deterministic() {
        let a = super::sweep_scenario(40);
        let b = super::sweep_scenario(40);
        assert_eq!(a.streams, b.streams);
        assert_eq!(a.rates, b.rates);
        assert_eq!(a.streams.len(), super::RATE_MULTS.len());
        assert!(a.streams.iter().all(|s| s.len() == 40));
    }
}
