//! PJRT runtime: load AOT HLO-text artifacts and execute them from Rust.
//!
//! The request-path half of the three-layer architecture: Python/JAX
//! lowers the Layer-2 GEMM (calling the Layer-1 Pallas kernel) to HLO
//! text once at build time (`make artifacts`); this module loads those
//! files with the `xla` crate (`PjRtClient::cpu` →
//! `HloModuleProto::from_text_file` → compile → execute) and serves
//! them to the coordinator with no Python anywhere near the hot path.
//!
//! HLO *text* is the interchange format (not serialized protos): jax
//! ≥ 0.5 emits 64-bit instruction ids that xla_extension 0.5.1 rejects;
//! the text parser reassigns ids (see /opt/xla-example/README.md).

pub mod worker;

use crate::blis::gemm::GemmShape;
use anyhow::{anyhow, bail, Context, Result};
use std::collections::HashMap;
use std::path::{Path, PathBuf};

/// One line of `artifacts/manifest.txt`:
/// `name m n k dtype variant file`.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ArtifactSpec {
    pub name: String,
    pub m: usize,
    pub n: usize,
    pub k: usize,
    pub dtype: String,
    pub variant: String,
    pub file: String,
}

impl ArtifactSpec {
    pub fn shape(&self) -> GemmShape {
        GemmShape {
            m: self.m,
            n: self.n,
            k: self.k,
        }
    }

    fn parse_line(line: &str) -> Result<ArtifactSpec> {
        let parts: Vec<&str> = line.split_whitespace().collect();
        if parts.len() != 7 {
            bail!("manifest line has {} fields, expected 7: '{line}'", parts.len());
        }
        Ok(ArtifactSpec {
            name: parts[0].to_string(),
            m: parts[1].parse().context("bad m")?,
            n: parts[2].parse().context("bad n")?,
            k: parts[3].parse().context("bad k")?,
            dtype: parts[4].to_string(),
            variant: parts[5].to_string(),
            file: parts[6].to_string(),
        })
    }
}

/// Parse `<dir>/manifest.txt`.
pub fn parse_manifest(dir: &Path) -> Result<Vec<ArtifactSpec>> {
    let path = dir.join("manifest.txt");
    let text = std::fs::read_to_string(&path)
        .with_context(|| format!("reading {path:?} — run `make artifacts` first"))?;
    text.lines()
        .filter(|l| !l.trim().is_empty())
        .map(ArtifactSpec::parse_line)
        .collect()
}

/// A compiled artifact ready to execute.
struct Loaded {
    spec: ArtifactSpec,
    exe: xla::PjRtLoadedExecutable,
}

/// The artifact store: a PJRT CPU client plus compiled executables,
/// keyed by artifact name. One compiled executable per model variant
/// and shape — compiled once at load, reused across requests.
pub struct Runtime {
    client: xla::PjRtClient,
    loaded: HashMap<String, Loaded>,
    dir: PathBuf,
}

impl Runtime {
    /// Create a runtime over a PJRT CPU client.
    pub fn new(artifact_dir: &Path) -> Result<Runtime> {
        let client = xla::PjRtClient::cpu().map_err(|e| anyhow!("PJRT client: {e:?}"))?;
        Ok(Runtime {
            client,
            loaded: HashMap::new(),
            dir: artifact_dir.to_path_buf(),
        })
    }

    /// Load + compile every artifact in the manifest.
    pub fn load_all(&mut self) -> Result<usize> {
        let specs = parse_manifest(&self.dir)?;
        let n = specs.len();
        for spec in specs {
            self.load(spec)?;
        }
        Ok(n)
    }

    /// Load + compile one artifact.
    pub fn load(&mut self, spec: ArtifactSpec) -> Result<()> {
        let path = self.dir.join(&spec.file);
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().context("non-utf8 path")?,
        )
        .map_err(|e| anyhow!("parsing HLO text {path:?}: {e:?}"))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .map_err(|e| anyhow!("compiling {}: {e:?}", spec.name))?;
        self.loaded.insert(spec.name.clone(), Loaded { spec, exe });
        Ok(())
    }

    pub fn names(&self) -> Vec<&str> {
        let mut v: Vec<&str> = self.loaded.keys().map(|s| s.as_str()).collect();
        v.sort();
        v
    }

    pub fn spec(&self, name: &str) -> Option<&ArtifactSpec> {
        self.loaded.get(name).map(|l| &l.spec)
    }

    /// Find a loaded artifact matching shape + variant.
    pub fn find(&self, shape: GemmShape, variant: &str) -> Option<&ArtifactSpec> {
        self.loaded
            .values()
            .map(|l| &l.spec)
            .find(|s| s.m == shape.m && s.n == shape.n && s.k == shape.k && s.variant == variant)
    }

    /// Execute `C = A·B` for a loaded artifact. `a` is row-major m×k,
    /// `b` is k×n; returns row-major m×n.
    pub fn execute(&self, name: &str, a: &[f64], b: &[f64]) -> Result<Vec<f64>> {
        let l = self
            .loaded
            .get(name)
            .with_context(|| format!("artifact '{name}' not loaded"))?;
        let (m, n, k) = (l.spec.m, l.spec.n, l.spec.k);
        if a.len() != m * k || b.len() != k * n {
            bail!(
                "operand sizes {}/{} do not match artifact {name} ({m}x{k}, {k}x{n})",
                a.len(),
                b.len()
            );
        }
        let lit_a = xla::Literal::vec1(a)
            .reshape(&[m as i64, k as i64])
            .map_err(|e| anyhow!("reshape A: {e:?}"))?;
        let lit_b = xla::Literal::vec1(b)
            .reshape(&[k as i64, n as i64])
            .map_err(|e| anyhow!("reshape B: {e:?}"))?;
        let result = l
            .exe
            .execute::<xla::Literal>(&[lit_a, lit_b])
            .map_err(|e| anyhow!("execute {name}: {e:?}"))?[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow!("fetch result: {e:?}"))?;
        // aot.py lowers with return_tuple=True → 1-tuple.
        let out = result
            .to_tuple1()
            .map_err(|e| anyhow!("untuple: {e:?}"))?;
        out.to_vec::<f64>().map_err(|e| anyhow!("to_vec: {e:?}"))
    }

    pub fn artifact_dir(&self) -> &Path {
        &self.dir
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::blis::gemm::gemm_naive;
    use crate::util::rng::Rng;
    use crate::util::stats::{gemm_tolerance, max_abs_diff};

    fn artifacts_dir() -> PathBuf {
        Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts")
    }

    fn have_artifacts() -> bool {
        artifacts_dir().join("manifest.txt").exists()
    }

    #[test]
    fn manifest_line_parsing() {
        let s = ArtifactSpec::parse_line("gemm_big_64 64 64 64 f64 big gemm_big_64.hlo.txt").unwrap();
        assert_eq!(s.name, "gemm_big_64");
        assert_eq!((s.m, s.n, s.k), (64, 64, 64));
        assert_eq!(s.variant, "big");
        assert!(ArtifactSpec::parse_line("too few fields").is_err());
    }

    #[test]
    fn manifest_parses_from_disk() {
        if !have_artifacts() {
            eprintln!("skipping: run `make artifacts` first");
            return;
        }
        let specs = parse_manifest(&artifacts_dir()).unwrap();
        assert!(specs.len() >= 9);
        assert!(specs.iter().any(|s| s.variant == "big"));
        assert!(specs.iter().any(|s| s.variant == "little"));
    }

    #[test]
    fn execute_matches_naive_gemm() {
        if !have_artifacts() {
            eprintln!("skipping: run `make artifacts` first");
            return;
        }
        let mut rt = Runtime::new(&artifacts_dir()).unwrap();
        let specs = parse_manifest(&artifacts_dir()).unwrap();
        let spec = specs.iter().find(|s| s.name == "gemm_big_64").unwrap().clone();
        rt.load(spec).unwrap();

        let mut rng = Rng::new(11);
        let a = rng.fill_matrix(64 * 64);
        let b = rng.fill_matrix(64 * 64);
        let got = rt.execute("gemm_big_64", &a, &b).unwrap();
        let mut want = vec![0.0; 64 * 64];
        gemm_naive(GemmShape { m: 64, n: 64, k: 64 }, &a, &b, &mut want);
        let d = max_abs_diff(&got, &want);
        assert!(d < gemm_tolerance(64), "diff {d}");
    }

    #[test]
    fn rectangular_artifact_matches() {
        if !have_artifacts() {
            eprintln!("skipping: run `make artifacts` first");
            return;
        }
        let mut rt = Runtime::new(&artifacts_dir()).unwrap();
        let specs = parse_manifest(&artifacts_dir()).unwrap();
        let spec = specs
            .iter()
            .find(|s| s.name == "gemm_big_96x160x224")
            .unwrap()
            .clone();
        let (m, n, k) = (spec.m, spec.n, spec.k);
        rt.load(spec).unwrap();
        let mut rng = Rng::new(12);
        let a = rng.fill_matrix(m * k);
        let b = rng.fill_matrix(k * n);
        let got = rt.execute("gemm_big_96x160x224", &a, &b).unwrap();
        let mut want = vec![0.0; m * n];
        gemm_naive(GemmShape { m, n, k }, &a, &b, &mut want);
        assert!(max_abs_diff(&got, &want) < gemm_tolerance(k));
    }

    #[test]
    fn wrong_operand_sizes_rejected() {
        if !have_artifacts() {
            eprintln!("skipping: run `make artifacts` first");
            return;
        }
        let mut rt = Runtime::new(&artifacts_dir()).unwrap();
        let specs = parse_manifest(&artifacts_dir()).unwrap();
        let spec = specs.iter().find(|s| s.name == "gemm_big_64").unwrap().clone();
        rt.load(spec).unwrap();
        assert!(rt.execute("gemm_big_64", &[0.0; 10], &[0.0; 10]).is_err());
        assert!(rt.execute("nope", &[], &[]).is_err());
    }

    #[test]
    fn find_by_shape_and_variant() {
        if !have_artifacts() {
            eprintln!("skipping: run `make artifacts` first");
            return;
        }
        let mut rt = Runtime::new(&artifacts_dir()).unwrap();
        rt.load_all().unwrap();
        let s = GemmShape { m: 128, n: 128, k: 128 };
        assert!(rt.find(s, "big").is_some());
        assert!(rt.find(s, "little").is_some());
        assert!(rt.find(GemmShape { m: 7, n: 7, k: 7 }, "big").is_none());
        assert!(rt.names().len() >= 9);
    }
}
