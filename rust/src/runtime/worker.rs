//! Thread-confined PJRT runtime.
//!
//! The `xla` crate's client/executable handles are `!Send` (internally
//! `Rc`), so they cannot live inside a shared `Mutex`. Instead a single
//! dedicated thread owns the [`Runtime`] and serves execution requests
//! over channels — the standard actor pattern. Latency impact is
//! negligible: one channel hop around a millisecond-scale GEMM.

use crate::blis::gemm::GemmShape;
use crate::runtime::Runtime;
use anyhow::{anyhow, Result};
use std::path::Path;
use std::sync::mpsc;

enum Msg {
    Execute {
        shape: GemmShape,
        variant: String,
        a: Vec<f64>,
        b: Vec<f64>,
        reply: mpsc::Sender<Result<(String, Vec<f64>)>>,
    },
    Names {
        reply: mpsc::Sender<Vec<String>>,
    },
    Has {
        shape: GemmShape,
        variant: String,
        reply: mpsc::Sender<bool>,
    },
    Shutdown,
}

/// Cloneable, `Send`+`Sync` handle to the runtime thread.
#[derive(Clone)]
pub struct PjrtHandle {
    tx: mpsc::Sender<Msg>,
}

impl PjrtHandle {
    /// Spawn the runtime thread, loading every artifact in `dir`.
    /// Blocks until loading finishes so failures surface immediately.
    pub fn spawn(dir: &Path) -> Result<PjrtHandle> {
        let (tx, rx) = mpsc::channel::<Msg>();
        let (ready_tx, ready_rx) = mpsc::channel::<Result<usize>>();
        let dir = dir.to_path_buf();
        std::thread::Builder::new()
            .name("pjrt-runtime".into())
            .spawn(move || {
                let mut rt = match Runtime::new(&dir).and_then(|mut rt| {
                    let n = rt.load_all()?;
                    Ok((rt, n))
                }) {
                    Ok((rt, n)) => {
                        let _ = ready_tx.send(Ok(n));
                        rt
                    }
                    Err(e) => {
                        let _ = ready_tx.send(Err(e));
                        return;
                    }
                };
                for msg in rx {
                    match msg {
                        Msg::Execute { shape, variant, a, b, reply } => {
                            let result = (|| {
                                let spec = rt
                                    .find(shape, &variant)
                                    .ok_or_else(|| {
                                        anyhow!(
                                            "no artifact for {}x{}x{} variant {variant}",
                                            shape.m, shape.n, shape.k
                                        )
                                    })?
                                    .clone();
                                let c = rt.execute(&spec.name, &a, &b)?;
                                Ok((spec.name, c))
                            })();
                            let _ = reply.send(result);
                        }
                        Msg::Names { reply } => {
                            let _ = reply
                                .send(rt.names().iter().map(|s| s.to_string()).collect());
                        }
                        Msg::Has { shape, variant, reply } => {
                            let _ = reply.send(rt.find(shape, &variant).is_some());
                        }
                        Msg::Shutdown => break,
                    }
                }
                // rt dropped here, on its owning thread.
                let _ = &mut rt;
            })
            .map_err(|e| anyhow!("spawning runtime thread: {e}"))?;
        ready_rx
            .recv()
            .map_err(|_| anyhow!("runtime thread died during load"))??;
        Ok(PjrtHandle { tx })
    }

    /// Execute `C = A·B` on the artifact matching (shape, variant).
    /// Returns (artifact name, result).
    pub fn execute(
        &self,
        shape: GemmShape,
        variant: &str,
        a: Vec<f64>,
        b: Vec<f64>,
    ) -> Result<(String, Vec<f64>)> {
        let (reply, rx) = mpsc::channel();
        self.tx
            .send(Msg::Execute {
                shape,
                variant: variant.to_string(),
                a,
                b,
                reply,
            })
            .map_err(|_| anyhow!("runtime thread gone"))?;
        rx.recv().map_err(|_| anyhow!("runtime thread dropped reply"))?
    }

    pub fn names(&self) -> Result<Vec<String>> {
        let (reply, rx) = mpsc::channel();
        self.tx
            .send(Msg::Names { reply })
            .map_err(|_| anyhow!("runtime thread gone"))?;
        rx.recv().map_err(|_| anyhow!("runtime thread dropped reply"))
    }

    /// Is an exact-shape artifact loaded for this (shape, variant)?
    pub fn has(&self, shape: GemmShape, variant: &str) -> Result<bool> {
        let (reply, rx) = mpsc::channel();
        self.tx
            .send(Msg::Has {
                shape,
                variant: variant.to_string(),
                reply,
            })
            .map_err(|_| anyhow!("runtime thread gone"))?;
        rx.recv().map_err(|_| anyhow!("runtime thread dropped reply"))
    }

    pub fn shutdown(&self) {
        let _ = self.tx.send(Msg::Shutdown);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::blis::gemm::gemm_naive;
    use crate::util::rng::Rng;
    use crate::util::stats::{gemm_tolerance, max_abs_diff};

    fn artifacts_dir() -> std::path::PathBuf {
        Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts")
    }

    fn have_artifacts() -> bool {
        artifacts_dir().join("manifest.txt").exists()
    }

    #[test]
    fn handle_executes_from_other_threads() {
        if !have_artifacts() {
            eprintln!("skipping: run `make artifacts` first");
            return;
        }
        let h = PjrtHandle::spawn(&artifacts_dir()).unwrap();
        let mut joins = Vec::new();
        for seed in 0..4u64 {
            let h = h.clone();
            joins.push(std::thread::spawn(move || {
                let mut rng = Rng::new(seed);
                let a = rng.fill_matrix(64 * 64);
                let b = rng.fill_matrix(64 * 64);
                let shape = GemmShape::square(64);
                let (name, c) = h.execute(shape, "big", a.clone(), b.clone()).unwrap();
                assert_eq!(name, "gemm_big_64");
                let mut want = vec![0.0; 64 * 64];
                gemm_naive(shape, &a, &b, &mut want);
                assert!(max_abs_diff(&c, &want) < gemm_tolerance(64));
            }));
        }
        for j in joins {
            j.join().unwrap();
        }
        h.shutdown();
    }

    #[test]
    fn missing_artifact_is_error_not_panic() {
        if !have_artifacts() {
            eprintln!("skipping: run `make artifacts` first");
            return;
        }
        let h = PjrtHandle::spawn(&artifacts_dir()).unwrap();
        let err = h
            .execute(GemmShape::square(33), "big", vec![0.0; 33 * 33], vec![0.0; 33 * 33])
            .unwrap_err();
        assert!(err.to_string().contains("no artifact"));
        h.shutdown();
    }

    #[test]
    fn bad_dir_fails_at_spawn() {
        let err = match PjrtHandle::spawn(Path::new("/nonexistent-dir")) {
            Err(e) => e,
            Ok(_) => panic!("spawn should fail for a missing manifest"),
        };
        assert!(err.to_string().contains("manifest"), "{err}");
    }

    #[test]
    fn names_listed() {
        if !have_artifacts() {
            eprintln!("skipping: run `make artifacts` first");
            return;
        }
        let h = PjrtHandle::spawn(&artifacts_dir()).unwrap();
        let names = h.names().unwrap();
        assert!(names.iter().any(|n| n == "gemm_little_256"));
        h.shutdown();
    }
}
