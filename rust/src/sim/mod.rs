//! Discrete-event simulation of GEMM schedules on the virtual AMP.
//!
//! `simulate(model, spec, shape)` is the workhorse behind every figure:
//! it executes a [`crate::sched::ScheduleSpec`] in virtual time over the
//! calibrated [`crate::model::PerfModel`] and returns a [`RunStats`]
//! with makespan, GFLOPS, per-core activity, DRAM traffic and the
//! energy report. See DESIGN.md §1 for why time is virtual while the
//! numerics run for real in `crate::native`.
//!
//! [`engine`] is the performance layer over the DES: a memoized
//! [`RunCache`] (fleet sweeps and DVFS replays re-price the same
//! configuration thousands of times) and an indexed [`EventQueue`] for
//! the streaming simulators. `simulate` itself is the no-trace fast
//! path; `simulate_traced` opts into timeline recording.

pub mod engine;
pub mod exec;
pub mod stats;
pub mod timeline;

pub use engine::{ConfigId, EventQueue, ItemCost, RunCache};
pub use exec::{simulate, simulate_traced};
pub use timeline::{PhaseKind, Timeline};
pub use stats::RunStats;
