//! The discrete-event simulator: executes a [`ScheduleSpec`] over the
//! virtual AMP topology and returns makespan, per-core activity, DRAM
//! traffic and energy.
//!
//! The simulation unit is a *cluster phase*: a packing pass or one
//! macro-kernel's fine-grain partition across a cluster's threads. Each
//! phase advances the cluster's virtual clock by the slowest thread's
//! share (plus barrier cost) and accrues per-thread busy/poll time —
//! exactly the lockstep structure of the real executor in
//! `crate::native`. The engine is cluster-count-agnostic: it drives one
//! [`ClusterSim`] per active cluster of the topology. Coarse-grain
//! interaction between clusters happens at three points, mirroring the
//! paper:
//!
//! * static Loop-1 coarse: none until the final join (§4/§5.2 — early
//!   clusters poll while the others finish);
//! * static/dynamic Loop-3 coarse: a global barrier per (jc, pc) pair,
//!   because `Bc` is shared and must not be repacked while in use;
//! * dynamic: a virtual critical section serializes chunk grabs
//!   (§5.4), ordered by cluster virtual time — any cluster grabs chunks
//!   of its *own* native `mc`.

use crate::blis::control_tree::ControlTree;
use crate::blis::gemm::GemmShape;
use crate::blis::packing::{pack_a_bytes, pack_b_bytes};
use crate::cache::analysis::FootprintAnalysis;
use crate::energy::{CoreActivity, PowerModel};
use crate::model::{MicroCtx, PerfModel};
use crate::partition::{split_weighted, Chunk};
use crate::sched::{CoarseLoop, ScheduleSpec, Strategy};
use crate::sim::stats::RunStats;
use crate::sim::timeline::{PhaseKind, Timeline};
use crate::soc::ClusterId;

/// Widest cluster the stack-allocated phase buffers and per-thread
/// accumulators support (perf pass: no heap allocation per simulated
/// phase or per ClusterSim, DESIGN.md §10).
const MAX_CLUSTER_THREADS: usize = 16;

/// One cluster's simulated execution state.
struct ClusterSim<'m> {
    cluster: ClusterId,
    threads: usize,
    tree: ControlTree,
    model: &'m PerfModel,
    clock: f64,
    busy: [f64; MAX_CLUSTER_THREADS],
    poll: [f64; MAX_CLUSTER_THREADS],
    grabs: u64,
    barriers: u64,
    dram_bytes: f64,
    /// Useful flops this cluster executed (2·mc·nc·kc per macro-kernel
    /// chunk) — the per-cluster attribution `RunStats::cluster_flops`
    /// surfaces for the live calibration layer.
    flops: f64,
    /// Whether at least one other cluster also computes in this run.
    other_active: bool,
    /// Does this cluster's `Ac` overflow its L2 (per-jr re-streaming)?
    ac_overflows: bool,
    /// Phase-level trace of this cluster's virtual time.
    timeline: Timeline,
    /// Whether to record timeline segments (perf: plain `simulate` skips
    /// recording; `simulate_traced` enables it).
    record: bool,
}

impl<'m> ClusterSim<'m> {
    fn new(
        model: &'m PerfModel,
        cluster: ClusterId,
        threads: usize,
        tree: ControlTree,
        other_active: bool,
    ) -> Self {
        assert!(threads <= MAX_CLUSTER_THREADS, "cluster too wide for the sim");
        // SLC-aware: an Ac spill caught by the system-level cache
        // re-streams from the L3, not DRAM (no extra DRAM traffic).
        let fit = FootprintAnalysis::for_cluster_in(&model.soc, cluster).fit(&tree.params);
        ClusterSim {
            cluster,
            threads,
            tree,
            model,
            clock: 0.0,
            busy: [0.0; MAX_CLUSTER_THREADS],
            poll: [0.0; MAX_CLUSTER_THREADS],
            grabs: 0,
            barriers: 0,
            dram_bytes: 0.0,
            flops: 0.0,
            other_active,
            ac_overflows: !fit.ac_fits() && !fit.ac_fits_l3(),
            timeline: Timeline::default(),
            record: false,
        }
    }

    /// Run one lockstep phase: each thread works `per_thread[i]` seconds,
    /// everyone waits for the slowest, then (optionally) pays a barrier.
    fn run_phase(&mut self, kind: PhaseKind, per_thread: &[f64], barrier: bool) {
        debug_assert_eq!(per_thread.len(), self.threads);
        let span = per_thread.iter().cloned().fold(0.0, f64::max);
        let b = if barrier {
            self.barriers += 1;
            self.model.barrier_time(self.cluster)
        } else {
            0.0
        };
        for i in 0..self.threads {
            self.busy[i] += per_thread[i];
            self.poll[i] += span - per_thread[i] + b;
        }
        if self.record {
            self.timeline.push(self.cluster, kind, self.clock, self.clock + span);
            self.timeline
                .push(self.cluster, PhaseKind::Barrier, self.clock + span, self.clock + span + b);
        }
        self.clock += span + b;
    }

    /// Packing phase: `bytes` of payload split evenly among threads.
    fn pack_phase(&mut self, kind: PhaseKind, bytes: usize, barrier: bool) {
        let share = bytes as f64 / self.threads as f64;
        let t = self.model.pack_time(self.cluster, share.ceil() as usize);
        let v = [t; MAX_CLUSTER_THREADS];
        self.dram_bytes += bytes as f64;
        self.run_phase(kind, &v[..self.threads], barrier);
    }

    /// Per-thread compute times for one macro-kernel over an
    /// `mc_eff × nc_eff × kc_eff` block under this cluster's fine-grain
    /// parallelization.
    fn macro_times(
        &self,
        mc_eff: usize,
        nc_eff: usize,
        kc_eff: usize,
    ) -> [f64; MAX_CLUSTER_THREADS] {
        let p = &self.tree.params;
        let n_jr = nc_eff.div_ceil(p.nr);
        let n_ir = mc_eff.div_ceil(p.mr);
        let w4 = self.tree.par.loop4_ways.min(self.threads).max(1);
        let w5 = (self.threads / w4).max(1);

        // Static symmetric fine split (BLIS default within a cluster).
        let jr_share = |i: usize| n_jr / w4 + usize::from(i < n_jr % w4);
        let ir_share = |i: usize| n_ir / w5 + usize::from(i < n_ir % w5);

        let mut times = [0.0; MAX_CLUSTER_THREADS];
        for t in 0..self.threads {
            let (i4, i5) = (t % w4, t / w4);
            if i5 >= w5 {
                continue; // surplus thread beyond the w4×w5 grid: no work
            }
            let jr_n = jr_share(i4);
            let ir_n = ir_share(i5);
            if jr_n == 0 || ir_n == 0 {
                continue;
            }
            let rows_per_jr = (ir_n * p.mr).min(mc_eff);
            let ctx = MicroCtx {
                kc_eff,
                rows_per_jr,
                active_in_cluster: self.threads,
                other_cluster_active: self.other_active,
            };
            let t_micro = self.model.micro_kernel_time(self.cluster, p, &ctx);
            times[t] = (jr_n * ir_n) as f64 * t_micro;
        }
        times
    }

    /// Process one Loop-3 chunk: pack `Ac`, barrier, macro-kernel, barrier.
    fn process_ic_chunk(&mut self, mc_eff: usize, nc_eff: usize, kc_eff: usize) {
        self.flops += 2.0 * mc_eff as f64 * nc_eff as f64 * kc_eff as f64;
        let pa = pack_a_bytes(mc_eff, kc_eff);
        self.pack_phase(PhaseKind::PackA, pa, true);
        if self.ac_overflows {
            // Ac re-streams from DRAM on every jr column (§4's penalty
            // visible on the DRAM rail).
            let n_jr = nc_eff.div_ceil(self.tree.params.nr) as f64;
            self.dram_bytes += (mc_eff * kc_eff * 8) as f64 * (n_jr - 1.0).max(0.0);
        }
        let times = self.macro_times(mc_eff, nc_eff, kc_eff);
        self.run_phase(PhaseKind::Compute, &times[..self.threads], true);
    }

    /// Walk this cluster's own (jc, pc, ic) nest over sub-ranges of the
    /// problem — the Loop-1-coarse execution body.
    fn run_own_nest(&mut self, m_range: Chunk, n_range: Chunk, k: usize) {
        if m_range.is_empty() || n_range.is_empty() || k == 0 {
            return;
        }
        let p = self.tree.params;
        let mut jc = 0;
        while jc < n_range.len {
            let nc_eff = (n_range.len - jc).min(p.nc);
            let mut pc = 0;
            while pc < k {
                let kc_eff = (k - pc).min(p.kc);
                self.pack_phase(PhaseKind::PackB, pack_b_bytes(kc_eff, nc_eff), true);
                let mut ic = 0;
                while ic < m_range.len {
                    let mc_eff = (m_range.len - ic).min(p.mc);
                    self.process_ic_chunk(mc_eff, nc_eff, kc_eff);
                    ic += p.mc;
                }
                pc += p.kc;
            }
            jc += p.nc;
        }
        // C is read+written once per pc block.
        let pc_trips = k.div_ceil(p.kc) as f64;
        self.dram_bytes += 16.0 * (m_range.len * n_range.len) as f64 * pc_trips;
    }

    /// Advance the cluster's clock to `t`, charging the gap as poll time
    /// (fast threads "remain idle but active, polling", §5.2.2).
    fn sync_to(&mut self, t: f64) {
        if t > self.clock {
            let gap = t - self.clock;
            for i in 0..self.threads {
                self.poll[i] += gap;
            }
            if self.record {
                self.timeline.push(self.cluster, PhaseKind::Poll, self.clock, t);
            }
            self.clock = t;
        }
    }
}

/// Simulate one GEMM run under `spec`. Deterministic. This is the
/// no-trace fast path: timeline recording stays off and no per-phase
/// trace is allocated; [`simulate_traced`] returns bit-for-bit the same
/// [`RunStats`] plus the trace.
pub fn simulate(model: &PerfModel, spec: &ScheduleSpec, shape: GemmShape) -> RunStats {
    simulate_impl(model, spec, shape, false).0
}

/// Like [`simulate`], additionally returning the merged phase-level
/// [`Timeline`] of every cluster (Gantt export, structure tests).
pub fn simulate_traced(
    model: &PerfModel,
    spec: &ScheduleSpec,
    shape: GemmShape,
) -> (RunStats, Timeline) {
    simulate_impl(model, spec, shape, true)
}

fn simulate_impl(
    model: &PerfModel,
    spec: &ScheduleSpec,
    shape: GemmShape,
    record: bool,
) -> (RunStats, Timeline) {
    spec.validate_for(&model.soc).expect("invalid spec");
    let soc = &model.soc;
    let th = spec.threads(soc);
    let trees = spec.tree_set(soc);
    let n_active = th.iter().filter(|&&t| t > 0).count();

    // One ClusterSim per *active* cluster, in ClusterId order.
    let mut sims: Vec<ClusterSim> = soc
        .cluster_ids()
        .filter(|c| th[c.0] > 0)
        .map(|c| {
            let mut sim = ClusterSim::new(
                model,
                c,
                th[c.0],
                trees.for_cluster(c).clone(),
                n_active > 1,
            );
            sim.record = record;
            sim
        })
        .collect();
    assert!(!sims.is_empty(), "no active cluster");

    let GemmShape { m, n, k } = shape;
    let full_m = Chunk { start: 0, len: m };
    let full_n = Chunk { start: 0, len: n };
    let lead_tree = trees.for_cluster(soc.lead());

    match (&spec.strategy, spec.coarse) {
        (Strategy::ClusterOnly { .. }, _) => {
            sims[0].run_own_nest(full_m, full_n, k);
        }
        // ---- static coarse split of Loop 1 (independent buffers) ----
        (Strategy::Sss | Strategy::Sas { .. } | Strategy::CaSas { .. }, CoarseLoop::Loop1) => {
            let w = spec.coarse_weights(soc).expect("static");
            let parts = split_weighted(n, &w, lead_tree.params.nr);
            for sim in sims.iter_mut() {
                sim.run_own_nest(full_m, parts[sim.cluster.0], k);
            }
            let t_end = sims.iter().map(|s| s.clock).fold(0.0, f64::max);
            for sim in sims.iter_mut() {
                sim.sync_to(t_end);
            }
        }
        // ---- static coarse split of Loop 3 (shared Bc) ----
        (Strategy::Sss | Strategy::Sas { .. } | Strategy::CaSas { .. }, CoarseLoop::Loop3) => {
            let w = spec.coarse_weights(soc).expect("static");
            let parts = split_weighted(m, &w, lead_tree.params.mr);
            run_shared_bc(&mut sims, shape, |sims, nc_eff, kc_eff| {
                for sim in sims.iter_mut() {
                    walk_m_range(sim, parts[sim.cluster.0], nc_eff, kc_eff);
                }
            });
        }
        // ---- dynamic distribution over Loop 3 (shared Bc) ----
        (Strategy::Das | Strategy::CaDas, _) => {
            run_shared_bc(&mut sims, shape, |sims, nc_eff, kc_eff| {
                dynamic_m_loop(sims, m, nc_eff, kc_eff);
            });
        }
    }

    // Gather global results.
    let time_s = sims.iter().map(|s| s.clock).fold(0.0, f64::max);
    let mut activity = vec![CoreActivity::default(); soc.total_cores()];
    for sim in &sims {
        for (i, gid) in soc.core_ids(sim.cluster).take(sim.threads).enumerate() {
            activity[gid] = CoreActivity {
                busy_s: sim.busy[i],
                poll_s: (sim.poll[i]).min(time_s - sim.busy[i]).max(0.0),
            };
        }
    }
    let mut cluster_flops = vec![0.0f64; soc.num_clusters()];
    for sim in &sims {
        cluster_flops[sim.cluster.0] = sim.flops;
    }
    let dram_bytes: f64 = sims.iter().map(|s| s.dram_bytes).sum();
    let power = PowerModel::new(soc.clone());
    let energy = power.integrate(time_s, &activity, dram_bytes);
    let flops = shape.flops();
    let mut timeline = Timeline::default();
    for sim in &sims {
        timeline.segments.extend(sim.timeline.segments.iter().copied());
    }
    let stats = RunStats {
        label: spec.label_on(soc),
        shape,
        time_s,
        flops,
        gflops: flops / time_s / 1e9,
        activity,
        cluster_flops,
        dram_bytes,
        gflops_per_watt: energy.gflops_per_watt(flops),
        energy,
        grabs: sims.iter().map(|s| s.grabs).sum(),
        barriers: sims.iter().map(|s| s.barriers).sum(),
    };
    (stats, timeline)
}

/// Shared-`Bc` outer structure (coarse Loop 3, §5.3/§5.4): Loop 1 and
/// Loop 2 are walked jointly; every cluster cooperates packing `Bc`,
/// syncs globally, runs `body` over the m space, and syncs again before
/// the next `Bc`.
fn run_shared_bc<'m>(
    sims: &mut [ClusterSim<'m>],
    shape: GemmShape,
    mut body: impl FnMut(&mut [ClusterSim<'m>], usize, usize),
) {
    let GemmShape { m, n, k } = shape;
    let nc = sims[0].tree.params.nc;
    let kc = sims[0].tree.params.kc;
    assert!(
        sims.iter().all(|s| s.tree.params.kc == kc && s.tree.params.nc == nc),
        "shared Bc requires common (nc, kc) strides (§5.3)"
    );
    let total_threads: usize = sims.iter().map(|s| s.threads).sum();
    let mut jc = 0;
    while jc < n {
        let nc_eff = (n - jc).min(nc);
        let mut pc = 0;
        while pc < k {
            let kc_eff = (k - pc).min(kc);
            // Cooperative Bc pack: even byte split across all threads.
            let bytes = pack_b_bytes(kc_eff, nc_eff);
            let share = bytes / total_threads + 1;
            for sim in sims.iter_mut() {
                let t = sim.model.pack_time(sim.cluster, share);
                let v = [t; MAX_CLUSTER_THREADS];
                sim.dram_bytes += bytes as f64 * sim.threads as f64 / total_threads as f64;
                sim.run_phase(PhaseKind::PackB, &v[..sim.threads], true);
            }
            global_sync(sims);

            body(sims, nc_eff, kc_eff);
            global_sync(sims);
            pc += kc;
        }
        jc += nc;
    }
    // C traffic: read+write once per pc block, split across clusters.
    let pc_trips = k.div_ceil(kc) as f64;
    let c_share = 16.0 * (m * n) as f64 * pc_trips / sims.len() as f64;
    for sim in sims.iter_mut() {
        sim.dram_bytes += c_share;
    }
}

/// Static walk of a cluster's m sub-range (coarse Loop 3).
fn walk_m_range(cl: &mut ClusterSim, range: Chunk, nc_eff: usize, kc_eff: usize) {
    let mc = cl.tree.params.mc;
    let mut ic = 0;
    while ic < range.len {
        let mc_eff = (range.len - ic).min(mc);
        cl.process_ic_chunk(mc_eff, nc_eff, kc_eff);
        ic += mc;
    }
}

/// Dynamic m-loop (§5.4): every cluster grabs chunks of its own `mc`
/// from a shared queue; grabs serialize through a virtual critical
/// section in virtual-time order (ties go to the lowest cluster id).
fn dynamic_m_loop(sims: &mut [ClusterSim], m: usize, nc_eff: usize, kc_eff: usize) {
    let mut next = 0usize; // queue head
    let mut cs_free = 0.0f64; // critical-section availability (virtual t)

    // Event loop: the cluster with the earliest clock grabs next.
    while next < m {
        let mut idx = 0;
        for (i, sim) in sims.iter().enumerate().skip(1) {
            if sim.clock < sims[idx].clock {
                idx = i;
            }
        }
        let cl = &mut sims[idx];

        // Enter the critical section.
        let t_start = cl.clock.max(cs_free);
        let wait = t_start - cl.clock;
        if wait > 0.0 {
            for i in 0..cl.threads {
                cl.poll[i] += wait;
            }
            if cl.record {
                cl.timeline.push(cl.cluster, PhaseKind::Poll, cl.clock, t_start);
            }
            cl.clock = t_start;
        }
        let g = cl.model.grab_time(cl.cluster);
        if cl.record {
            cl.timeline.push(cl.cluster, PhaseKind::Grab, cl.clock, cl.clock + g);
        }
        cl.clock += g;
        for i in 0..cl.threads {
            cl.poll[i] += g; // broadcast wait while the lead thread grabs
        }
        cs_free = cl.clock;
        cl.grabs += 1;

        let mc = cl.tree.params.mc;
        let take = mc.min(m - next);
        next += take;
        cl.process_ic_chunk(take, nc_eff, kc_eff);
    }
}

/// Sync every cluster to the same virtual instant (global barrier),
/// charging poll time to the early ones.
fn global_sync(sims: &mut [ClusterSim]) {
    let t = sims.iter().map(|s| s.clock).fold(0.0, f64::max);
    for sim in sims.iter_mut() {
        sim.sync_to(t);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sched::{FineLoop, ScheduleSpec, Strategy, Weights};
    use crate::soc::{SocSpec, BIG, LITTLE};

    fn model() -> PerfModel {
        PerfModel::exynos()
    }

    fn run(spec: ScheduleSpec, r: usize) -> RunStats {
        simulate(&model(), &spec, GemmShape::square(r))
    }

    /// §3.4: isolated-cluster peaks at a large size.
    #[test]
    fn isolated_cluster_peaks() {
        let big4 = run(ScheduleSpec::cluster_only(BIG, 4), 4096);
        assert!((8.8..10.0).contains(&big4.gflops), "A15×4: {}", big4.gflops);
        let little4 = run(ScheduleSpec::cluster_only(LITTLE, 4), 4096);
        assert!((2.0..2.5).contains(&little4.gflops), "A7×4: {}", little4.gflops);
        let big1 = run(ScheduleSpec::cluster_only(BIG, 1), 4096);
        assert!((2.6..3.0).contains(&big1.gflops), "A15×1: {}", big1.gflops);
    }

    /// §4: SSS on 8 cores delivers ≈ 40 % of the A15-only peak.
    #[test]
    fn sss_is_architecture_oblivious_disaster() {
        let sss = run(ScheduleSpec::sss(), 4096);
        let a15 = run(ScheduleSpec::cluster_only(BIG, 4), 4096);
        let frac = sss.gflops / a15.gflops;
        assert!((0.32..0.50).contains(&frac), "SSS fraction {frac}");
        // Big cores poll more than half the run (§4's imbalance).
        let big_poll: f64 = sss.activity[..4].iter().map(|a| a.poll_s).sum();
        let big_busy: f64 = sss.activity[..4].iter().map(|a| a.busy_s).sum();
        assert!(big_poll > big_busy, "big cluster should mostly poll");
    }

    /// Fig. 9: SAS performance peaks at ratio 5–6 and beats A15-only by
    /// ≈ 20 % at large sizes.
    #[test]
    fn sas_ratio_sweep_shape() {
        let g: Vec<f64> = (1..=7)
            .map(|r| run(ScheduleSpec::sas(r as f64), 4096).gflops)
            .collect();
        let best = (1..=7).max_by(|&a, &b| g[a - 1].total_cmp(&g[b - 1])).unwrap();
        assert!(
            (5..=6).contains(&best),
            "best ratio {best}, curve {g:?}"
        );
        let a15 = run(ScheduleSpec::cluster_only(BIG, 4), 4096).gflops;
        let gain = g[best - 1] / a15;
        assert!((1.10..1.30).contains(&gain), "gain over A15-only {gain}");
        // Ratio 1 (homogeneous) is the worst.
        let worst = (1..=7).min_by(|&a, &b| g[a - 1].total_cmp(&g[b - 1])).unwrap();
        assert_eq!(worst, 1, "curve {g:?}");
    }

    /// Fig. 10: CA-SAS ≥ SAS, with visible gains at ratios below 5.
    #[test]
    fn ca_sas_beats_sas_at_low_ratio() {
        for ratio in [1.0, 3.0] {
            let sas = run(ScheduleSpec::sas(ratio), 4096).gflops;
            let ca = run(ScheduleSpec::ca_sas(ratio), 4096).gflops;
            assert!(ca > sas * 1.05, "ratio {ratio}: CA {ca} vs SAS {sas}");
        }
        // At ratio 5, the difference vanishes (big cluster is critical).
        let sas5 = run(ScheduleSpec::sas(5.0), 4096).gflops;
        let ca5 = run(ScheduleSpec::ca_sas(5.0), 4096).gflops;
        assert!((ca5 / sas5 - 1.0).abs() < 0.05, "{sas5} vs {ca5}");
    }

    /// Fig. 12: CA-DAS (L3 dynamic + L4 fine) is the best configuration
    /// and clearly beats oblivious DAS.
    #[test]
    fn ca_das_wins() {
        let cadas = run(ScheduleSpec::ca_das(), 4096);
        let das = run(ScheduleSpec::das(), 4096);
        assert!(cadas.gflops > das.gflops * 1.05, "{} vs {}", cadas.gflops, das.gflops);
        let best_casas = run(ScheduleSpec::ca_sas(5.0), 4096).gflops;
        assert!(
            cadas.gflops > best_casas * 0.97,
            "CA-DAS {} should match/beat best CA-SAS {best_casas}",
            cadas.gflops
        );
        // Close to the ideal aggregate.
        let ideal = run(ScheduleSpec::cluster_only(BIG, 4), 4096).gflops
            + run(ScheduleSpec::cluster_only(LITTLE, 4), 4096).gflops;
        assert!(cadas.gflops > 0.90 * ideal, "CA-DAS {} vs ideal {ideal}", cadas.gflops);
        assert!(cadas.grabs > 0, "dynamic runs must grab chunks");
    }

    /// Fig. 11/12: fine-grain Loop 4 beats Loop 5.
    #[test]
    fn loop4_fine_beats_loop5() {
        let l4 = run(
            ScheduleSpec::new(Strategy::CaDas, CoarseLoop::Loop3, FineLoop::Loop4),
            4096,
        );
        let l5 = run(
            ScheduleSpec::new(Strategy::CaDas, CoarseLoop::Loop3, FineLoop::Loop5),
            4096,
        );
        assert!(l4.gflops > l5.gflops * 1.03, "{} vs {}", l4.gflops, l5.gflops);
    }

    /// §5.2.2: small problems can't exploit the asymmetry (SAS at small
    /// r falls below its large-size efficiency).
    #[test]
    fn small_problems_underperform() {
        let small = run(ScheduleSpec::sas(5.0), 256);
        let large = run(ScheduleSpec::sas(5.0), 4096);
        assert!(small.gflops < 0.8 * large.gflops, "{} vs {}", small.gflops, large.gflops);
    }

    /// Energy shape (§4/Fig. 7): SSS has by far the worst GFLOPS/W;
    /// well-balanced SAS ≈ A15-only.
    #[test]
    fn energy_ordering() {
        let sss = run(ScheduleSpec::sss(), 4096);
        let sas5 = run(ScheduleSpec::sas(5.0), 4096);
        let a15 = run(ScheduleSpec::cluster_only(BIG, 4), 4096);
        assert!(sss.gflops_per_watt < 0.7 * a15.gflops_per_watt);
        let rel = (sas5.gflops_per_watt / a15.gflops_per_watt - 1.0).abs();
        assert!(rel < 0.20, "SAS vs A15-only efficiency rel diff {rel}");
    }

    /// Work conservation: busy time × rate ≈ flops for every strategy
    /// (sanity on the phase accounting).
    #[test]
    fn activity_is_consistent() {
        for spec in [
            ScheduleSpec::sss(),
            ScheduleSpec::sas(3.0),
            ScheduleSpec::ca_sas(5.0),
            ScheduleSpec::das(),
            ScheduleSpec::ca_das(),
            ScheduleSpec::cluster_only(BIG, 2),
            ScheduleSpec::cluster_only(LITTLE, 3),
        ] {
            let st = run(spec, 1024);
            assert!(st.time_s > 0.0);
            assert!(st.gflops > 0.0);
            for (id, a) in st.activity.iter().enumerate() {
                assert!(
                    a.busy_s + a.poll_s <= st.time_s * 1.0000001 + 1e-12,
                    "{}: core {id} busy {} poll {} > T {}",
                    st.label,
                    a.busy_s,
                    a.poll_s,
                    st.time_s
                );
            }
            // Energy must be finite and positive.
            assert!(st.energy.energy_j > 0.0);
            assert!(st.gflops_per_watt > 0.0);
        }
    }

    /// Loop-1 vs Loop-3 static coarse under Loop-4 fine: no noticeable
    /// difference (Fig. 11's observation).
    #[test]
    fn coarse_loop_choice_irrelevant_under_l4() {
        let w = Weights::ratio(5.0);
        let l1 = run(
            ScheduleSpec::new(Strategy::CaSas { weights: w }, CoarseLoop::Loop1, FineLoop::Loop4),
            4096,
        );
        let l3 = run(
            ScheduleSpec::new(Strategy::CaSas { weights: w }, CoarseLoop::Loop3, FineLoop::Loop4),
            4096,
        );
        let rel = (l1.gflops / l3.gflops - 1.0).abs();
        assert!(rel < 0.10, "L1 {} vs L3 {}", l1.gflops, l3.gflops);
    }

    /// Timeline structure: valid per-cluster ordering, span == makespan,
    /// and the SSS imbalance shows as a long big-cluster poll tail.
    #[test]
    fn timeline_structure() {
        use crate::sim::timeline::PhaseKind;
        let (st, tl) =
            super::simulate_traced(&model(), &ScheduleSpec::sss(), GemmShape::square(2048));
        tl.validate().unwrap();
        assert!((tl.span() - st.time_s).abs() < 1e-9);
        let big_poll = tl.total(BIG, PhaseKind::Poll);
        assert!(big_poll > 0.5 * st.time_s, "SSS big poll tail {big_poll} of {}", st.time_s);
        let (st2, tl2) =
            super::simulate_traced(&model(), &ScheduleSpec::ca_das(), GemmShape::square(2048));
        tl2.validate().unwrap();
        assert!(tl2.total(BIG, PhaseKind::Grab) > 0.0);
        let poll2 = tl2.total(BIG, PhaseKind::Poll);
        assert!(poll2 < 0.1 * st2.time_s, "CA-DAS big poll {poll2} of {}", st2.time_s);
        // Compute dominates everything else for the balanced schedule.
        let compute = tl2.total(BIG, PhaseKind::Compute);
        assert!(compute > 0.8 * st2.time_s);
    }

    /// The no-trace fast path is the same simulation as the traced one:
    /// every `RunStats` field — makespan, activity, energy, counters —
    /// matches bit for bit, and only the traced run carries segments.
    #[test]
    fn untraced_fast_path_matches_traced_bit_for_bit() {
        let tri = PerfModel::new(SocSpec::dynamiq_3c());
        let cases = [
            (model(), ScheduleSpec::sss()),
            (model(), ScheduleSpec::sas(5.0)),
            (model(), ScheduleSpec::ca_sas(5.0)),
            (model(), ScheduleSpec::ca_das()),
            (tri, ScheduleSpec::das()),
        ];
        for (m, spec) in &cases {
            let fast = simulate(m, spec, GemmShape::square(1024));
            let (traced, tl) = super::simulate_traced(m, spec, GemmShape::square(1024));
            assert_eq!(fast, traced, "{}", fast.label);
            assert!(!tl.segments.is_empty(), "{}", fast.label);
        }
    }

    #[test]
    fn deterministic() {
        let a = run(ScheduleSpec::ca_das(), 1536);
        let b = run(ScheduleSpec::ca_das(), 1536);
        assert_eq!(a.time_s, b.time_s);
        assert_eq!(a.grabs, b.grabs);
        assert_eq!(a.energy.energy_j, b.energy.energy_j);
    }

    #[test]
    fn non_square_shapes() {
        let st = simulate(
            &model(),
            &ScheduleSpec::ca_das(),
            GemmShape { m: 1000, n: 300, k: 2000 },
        );
        assert!(st.gflops > 1.0);
        let tall = simulate(
            &model(),
            &ScheduleSpec::sas(5.0),
            GemmShape { m: 8192, n: 64, k: 64 },
        );
        assert!(tall.time_s > 0.0);
    }

    /// The N-cluster engine on a tri-cluster topology: every strategy
    /// family runs, is bounded by the aggregate, and CA-DAS stays close
    /// to the three-cluster ideal without any per-topology retuning.
    #[test]
    fn tri_cluster_topology_simulates() {
        let tri = PerfModel::new(SocSpec::dynamiq_3c());
        let ideal: f64 = tri
            .soc
            .cluster_ids()
            .map(|c| {
                simulate(
                    &tri,
                    &ScheduleSpec::cluster_only(c, tri.soc[c].num_cores),
                    GemmShape::square(4096),
                )
                .gflops
            })
            .sum();
        let w = tri.ca_sas_weights();
        for spec in [
            ScheduleSpec::sss(),
            ScheduleSpec::sas_weighted(tri.sas_weights()),
            ScheduleSpec::ca_sas_weighted(w),
            ScheduleSpec::das(),
            ScheduleSpec::ca_das(),
        ] {
            let st = simulate(&tri, &spec, GemmShape::square(4096));
            assert!(st.gflops > 0.0 && st.gflops < ideal * 1.001, "{}", st.label);
            assert_eq!(st.activity.len(), 9);
        }
        let cadas = simulate(&tri, &ScheduleSpec::ca_das(), GemmShape::square(4096));
        assert!(
            cadas.gflops > 0.85 * ideal,
            "tri-cluster CA-DAS {} vs ideal {ideal}",
            cadas.gflops
        );
        assert!(cadas.grabs > 0);
    }

    /// Symmetric degenerate case: on a single-cluster SMP the
    /// asymmetric machinery collapses — SSS, uniform SAS and the
    /// dynamic strategies all land within a few percent.
    #[test]
    fn symmetric_topology_collapses_strategies() {
        let smp = PerfModel::new(SocSpec::symmetric(4));
        let sss = simulate(&smp, &ScheduleSpec::sss(), GemmShape::square(2048)).gflops;
        let sas = simulate(
            &smp,
            &ScheduleSpec::sas_weighted(Weights::uniform(1)),
            GemmShape::square(2048),
        )
        .gflops;
        let cadas = simulate(&smp, &ScheduleSpec::ca_das(), GemmShape::square(2048)).gflops;
        assert!((sss / sas - 1.0).abs() < 1e-9, "SSS {sss} vs SAS {sas}");
        assert!((cadas / sss - 1.0).abs() < 0.05, "CA-DAS {cadas} vs SSS {sss}");
    }
}
