//! Phase-level timeline recording for simulated runs.
//!
//! The aggregate [`crate::sim::RunStats`] answers "how fast / how much
//! energy"; the timeline answers *why*: which cluster was packing,
//! computing, grabbing or polling at each point of virtual time. It
//! powers the Gantt-style CSV export (plot-ready), the per-phase
//! breakdown in the energy example, and regression tests on the
//! schedule *structure* (e.g. SSS's long big-cluster poll tail).
//! Segments are keyed by [`ClusterId`], so a timeline carries any
//! number of clusters.

use crate::soc::{ClusterId, SocSpec};
use crate::util::table::Table;

/// What a cluster is doing during a segment.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum PhaseKind {
    PackB,
    PackA,
    Compute,
    Grab,
    Barrier,
    Poll,
}

impl PhaseKind {
    pub fn name(self) -> &'static str {
        match self {
            PhaseKind::PackB => "pack_b",
            PhaseKind::PackA => "pack_a",
            PhaseKind::Compute => "compute",
            PhaseKind::Grab => "grab",
            PhaseKind::Barrier => "barrier",
            PhaseKind::Poll => "poll",
        }
    }
    pub const ALL: [PhaseKind; 6] = [
        PhaseKind::PackB,
        PhaseKind::PackA,
        PhaseKind::Compute,
        PhaseKind::Grab,
        PhaseKind::Barrier,
        PhaseKind::Poll,
    ];
}

/// One contiguous span of a cluster's virtual time.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Segment {
    pub cluster: ClusterId,
    pub kind: PhaseKind,
    pub t0: f64,
    pub t1: f64,
}

impl Segment {
    pub fn dur(&self) -> f64 {
        self.t1 - self.t0
    }
}

/// A recorded timeline (per-cluster segments, in emission order).
#[derive(Debug, Clone, Default)]
pub struct Timeline {
    pub segments: Vec<Segment>,
}

impl Timeline {
    pub fn push(&mut self, cluster: ClusterId, kind: PhaseKind, t0: f64, t1: f64) {
        debug_assert!(t1 >= t0 - 1e-15, "segment must not run backwards");
        if t1 > t0 {
            self.segments.push(Segment { cluster, kind, t0, t1 });
        }
    }

    /// Total time a cluster spent in a phase kind.
    pub fn total(&self, cluster: ClusterId, kind: PhaseKind) -> f64 {
        self.segments
            .iter()
            .filter(|s| s.cluster == cluster && s.kind == kind)
            .map(Segment::dur)
            .sum()
    }

    /// End of the last segment (the makespan seen by the timeline).
    pub fn span(&self) -> f64 {
        self.segments.iter().map(|s| s.t1).fold(0.0, f64::max)
    }

    /// Cluster ids that appear in this timeline, ascending.
    pub fn clusters(&self) -> Vec<ClusterId> {
        let mut ids: Vec<ClusterId> = self.segments.iter().map(|s| s.cluster).collect();
        ids.sort();
        ids.dedup();
        ids
    }

    /// Verify per-cluster segments are non-overlapping and ordered —
    /// the structural invariant of a lockstep cluster.
    pub fn validate(&self) -> Result<(), String> {
        for cluster in self.clusters() {
            let mut last_end = 0.0f64;
            for s in self.segments.iter().filter(|s| s.cluster == cluster) {
                if s.t0 < last_end - 1e-9 {
                    return Err(format!(
                        "{} segment at {} overlaps previous end {}",
                        cluster, s.t0, last_end
                    ));
                }
                last_end = s.t1;
            }
        }
        Ok(())
    }

    /// Per-cluster × per-phase breakdown table. Pass the SoC to label
    /// rows with cluster short names; without it rows use `c0`, `c1`, …
    pub fn breakdown(&self, soc: Option<&SocSpec>) -> Table {
        let mut t = Table::new(
            "Timeline breakdown [s]",
            &["cluster", "pack_b", "pack_a", "compute", "grab", "barrier", "poll", "total"],
        );
        for cluster in self.clusters() {
            let vals: Vec<f64> = PhaseKind::ALL
                .iter()
                .map(|&k| self.total(cluster, k))
                .collect();
            let total: f64 = vals.iter().sum();
            let label = match soc {
                Some(s) => s[cluster].short_name.clone(),
                None => cluster.label(),
            };
            let mut row = vec![label];
            row.extend(vals.iter().map(|v| format!("{v:.4}")));
            row.push(format!("{total:.4}"));
            t.push_row(row);
        }
        t
    }

    /// Gantt-style CSV (one row per segment): plot-ready.
    pub fn to_gantt_table(&self) -> Table {
        let mut t = Table::new("Gantt segments", &["cluster", "phase", "t0", "t1"]);
        for s in &self.segments {
            t.push_row(vec![
                s.cluster.label(),
                s.kind.name().to_string(),
                format!("{:.6}", s.t0),
                format!("{:.6}", s.t1),
            ]);
        }
        t
    }

    /// Emit every segment into a [`crate::obs::TraceSink`] as a
    /// complete span on the cluster's track: process `pid`, thread
    /// `tid_base + cluster`, timestamps shifted by `offset_s` (the
    /// item's virtual start instant inside a larger replay). The CSV
    /// export above is untouched — the sink is an additional
    /// consumer, not a replacement.
    pub fn emit_to(
        &self,
        sink: &mut dyn crate::obs::TraceSink,
        pid: usize,
        tid_base: usize,
        offset_s: f64,
    ) {
        if !sink.enabled() {
            return;
        }
        for s in &self.segments {
            sink.record(crate::obs::TraceEvent::span(
                s.kind.name(),
                "phase",
                pid,
                tid_base + s.cluster.0,
                offset_s + s.t0,
                s.dur(),
            ));
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::soc::{BIG, LITTLE};

    fn sample() -> Timeline {
        let mut tl = Timeline::default();
        tl.push(BIG, PhaseKind::PackB, 0.0, 0.1);
        tl.push(BIG, PhaseKind::Compute, 0.1, 0.9);
        tl.push(BIG, PhaseKind::Poll, 0.9, 1.0);
        tl.push(LITTLE, PhaseKind::PackB, 0.0, 0.3);
        tl.push(LITTLE, PhaseKind::Compute, 0.3, 1.0);
        tl
    }

    #[test]
    fn totals_and_span() {
        let tl = sample();
        assert!((tl.total(BIG, PhaseKind::Compute) - 0.8).abs() < 1e-12);
        assert!((tl.total(LITTLE, PhaseKind::Poll)).abs() < 1e-12);
        assert!((tl.span() - 1.0).abs() < 1e-12);
        assert_eq!(tl.clusters(), vec![BIG, LITTLE]);
    }

    #[test]
    fn zero_length_segments_dropped() {
        let mut tl = Timeline::default();
        tl.push(BIG, PhaseKind::Grab, 0.5, 0.5);
        assert!(tl.segments.is_empty());
    }

    #[test]
    fn validate_catches_overlap() {
        let mut tl = sample();
        assert!(tl.validate().is_ok());
        tl.push(BIG, PhaseKind::Compute, 0.5, 0.6); // overlaps
        assert!(tl.validate().is_err());
    }

    #[test]
    fn breakdown_table_shape() {
        let t = sample().breakdown(None);
        assert_eq!(t.rows.len(), 2);
        assert_eq!(t.columns.len(), 8);
        assert_eq!(t.rows[0][0], "c0");
        let named = sample().breakdown(Some(&SocSpec::exynos5422()));
        assert_eq!(named.rows[0][0], "big");
    }

    #[test]
    fn gantt_rows_match_segments() {
        let tl = sample();
        assert_eq!(tl.to_gantt_table().rows.len(), tl.segments.len());
    }

    #[test]
    fn many_cluster_timeline_validates() {
        let mut tl = Timeline::default();
        for i in 0..5 {
            tl.push(ClusterId(i), PhaseKind::Compute, 0.0, 1.0 + i as f64);
        }
        tl.validate().unwrap();
        assert_eq!(tl.clusters().len(), 5);
        assert!((tl.span() - 5.0).abs() < 1e-12);
    }
}
