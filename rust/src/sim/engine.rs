//! Sim-engine performance layer: a memoized run cache over the DES and
//! an indexed event queue for the streaming simulators (the ROADMAP
//! "million-event DES" item).
//!
//! **[`RunCache`]** memoizes [`crate::sim::simulate`] results by
//! *configuration fingerprint* × [`GemmShape`]. A configuration is the
//! exact `(SocSpec, ScheduleSpec)` pair the DES would execute — the
//! calibrate layer already establishes that a run's statistics depend
//! on nothing else, and a DVFS rung vector is covered for free because
//! callers fingerprint the *derived* at-OPP descriptor
//! ([`crate::dvfs::DvfsSchedule::soc_at`]). Fingerprints are the
//! `Debug` rendering of that pair: Rust formats `f64` with
//! shortest-round-trip precision, so two configurations share a
//! fingerprint iff they are value-equal. Interning the string to a
//! [`ConfigId`] turns the fleet layer's former O(n²) linear-scan board
//! dedup into id lookups and lets one cache serve a whole sweep
//! (capacity planning, wave replays, trajectory suites). Hits and
//! misses are counted: `misses()` is exactly the number of DES runs
//! executed, the deterministic counter the perf-trajectory suite gates.
//!
//! **[`EventQueue`]** is a binary min-heap keyed `(time, tie, seq)`:
//! NaN-safe [`f64::total_cmp`] ordering on time, a caller-chosen
//! integer tie rank, and a monotone sequence number so equal keys pop
//! in insertion order (the stable-sort contract of the sorted-`Vec`
//! bookkeeping it replaces, at O(log n) per event instead of
//! sort-after-the-fact).
//!
//! Both structures are pure bookkeeping: cached and fresh runs, and
//! heap-ordered and sort-ordered replays, are bit-for-bit identical
//! (property-tested in `tests/stream_props.rs` / `tests/dvfs_props.rs`).

use crate::blis::gemm::GemmShape;
use crate::model::PerfModel;
use crate::sched::ScheduleSpec;
use crate::sim::exec::simulate;
use crate::sim::stats::RunStats;
use std::cmp::Ordering;
use std::collections::hash_map::Entry;
use std::collections::HashMap;

/// Interned handle for one DES configuration (descriptor + schedule).
/// Equal ids ⇔ value-equal configurations within one [`RunCache`].
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct ConfigId(usize);

/// The two numbers the fleet hot loops price an item with — `Copy`, so
/// per-grab lookups never clone a [`RunStats`] (label string, per-core
/// activity vector, energy report).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct ItemCost {
    pub time_s: f64,
    pub energy_j: f64,
}

/// Memoized DES runs: `(ConfigId, GemmShape) → RunStats`, with interned
/// configuration fingerprints and hit/miss counters.
#[derive(Debug, Default)]
pub struct RunCache {
    ids: HashMap<String, usize>,
    runs: HashMap<(usize, GemmShape), RunStats>,
    hits: u64,
    misses: u64,
}

impl RunCache {
    pub fn new() -> RunCache {
        RunCache::default()
    }

    /// The configuration fingerprint: the `Debug` rendering of the
    /// descriptor and the schedule. `f64` debug-formats with
    /// shortest-round-trip precision, so value-equal configurations —
    /// and only those — collide.
    pub fn fingerprint(model: &PerfModel, spec: &ScheduleSpec) -> String {
        format!("{:?}#{:?}", model.soc, spec)
    }

    /// Intern a raw fingerprint string to its [`ConfigId`].
    pub fn intern(&mut self, fingerprint: String) -> ConfigId {
        let next = self.ids.len();
        ConfigId(*self.ids.entry(fingerprint).or_insert(next))
    }

    /// Intern `(model, spec)`: the id every lookup for this
    /// configuration keys on. Does not touch the hit/miss counters.
    pub fn config(&mut self, model: &PerfModel, spec: &ScheduleSpec) -> ConfigId {
        self.intern(Self::fingerprint(model, spec))
    }

    /// The memoized run for `(cfg, shape)`, executing `des` only on a
    /// miss. Counts one hit or one miss.
    pub fn run_with(
        &mut self,
        cfg: ConfigId,
        shape: GemmShape,
        des: impl FnOnce() -> RunStats,
    ) -> &RunStats {
        match self.runs.entry((cfg.0, shape)) {
            Entry::Occupied(e) => {
                self.hits += 1;
                e.into_mut()
            }
            Entry::Vacant(v) => {
                self.misses += 1;
                v.insert(des())
            }
        }
    }

    /// Convenience: intern and run in one call.
    pub fn run(&mut self, model: &PerfModel, spec: &ScheduleSpec, shape: GemmShape) -> &RunStats {
        let cfg = self.config(model, spec);
        self.run_with(cfg, shape, || simulate(model, spec, shape))
    }

    /// [`RunCache::run_with`] reduced to the `Copy` per-item cost the
    /// fleet hot loops need.
    pub fn cost_with(
        &mut self,
        cfg: ConfigId,
        shape: GemmShape,
        des: impl FnOnce() -> RunStats,
    ) -> ItemCost {
        let st = self.run_with(cfg, shape, des);
        ItemCost { time_s: st.time_s, energy_j: st.energy.energy_j }
    }

    /// Read a cached run without counting a lookup (post-processing
    /// passes that re-read runs the replay already executed).
    pub fn peek(&self, cfg: ConfigId, shape: GemmShape) -> Option<&RunStats> {
        self.runs.get(&(cfg.0, shape))
    }

    /// Lookups served from the cache.
    pub fn hits(&self) -> u64 {
        self.hits
    }

    /// Lookups that executed a DES run — the "DES runs performed"
    /// counter the perf-trajectory suite pins.
    pub fn misses(&self) -> u64 {
        self.misses
    }

    /// `hits / (hits + misses)`, 0 for an untouched cache.
    pub fn hit_rate(&self) -> f64 {
        let lookups = self.hits + self.misses;
        if lookups == 0 {
            0.0
        } else {
            self.hits as f64 / lookups as f64
        }
    }

    /// Distinct configurations interned so far.
    pub fn configs(&self) -> usize {
        self.ids.len()
    }

    /// Distinct `(configuration, shape)` runs held.
    pub fn cached_runs(&self) -> usize {
        self.runs.len()
    }

    /// Snapshot the cache counters into a metrics registry
    /// (`run_cache_hits`, `run_cache_misses`, `run_cache_configs`,
    /// `run_cache_cached_runs`, `run_cache_hit_rate`). A no-op on a
    /// disabled registry.
    pub fn export_metrics(&self, reg: &mut crate::obs::MetricsRegistry) {
        if !reg.enabled() {
            return;
        }
        reg.inc("run_cache_hits", self.hits as f64);
        reg.inc("run_cache_misses", self.misses as f64);
        reg.set_gauge("run_cache_configs", self.configs() as f64);
        reg.set_gauge("run_cache_cached_runs", self.cached_runs() as f64);
        reg.set_gauge("run_cache_hit_rate", self.hit_rate());
    }
}

#[derive(Debug, Clone)]
struct Event<T> {
    time: f64,
    tie: i64,
    seq: u64,
    payload: T,
}

/// Indexed event queue: a binary min-heap ordered by
/// `(time via total_cmp, tie, insertion seq)`. Equal `(time, tie)` keys
/// pop in push order, so it is a drop-in for "push everything, stable
/// sort, scan" bookkeeping — including NaN inputs, which order last
/// instead of panicking.
#[derive(Debug, Clone)]
pub struct EventQueue<T> {
    heap: Vec<Event<T>>,
    seq: u64,
}

impl<T> Default for EventQueue<T> {
    fn default() -> Self {
        EventQueue::new()
    }
}

impl<T> EventQueue<T> {
    pub fn new() -> EventQueue<T> {
        EventQueue { heap: Vec::new(), seq: 0 }
    }

    pub fn with_capacity(cap: usize) -> EventQueue<T> {
        EventQueue { heap: Vec::with_capacity(cap), seq: 0 }
    }

    pub fn len(&self) -> usize {
        self.heap.len()
    }

    pub fn is_empty(&self) -> bool {
        self.heap.is_empty()
    }

    /// Push at `time` with the neutral tie rank 0.
    pub fn push(&mut self, time: f64, payload: T) {
        self.push_tied(time, 0, payload);
    }

    /// Push at `time` with an explicit tie rank: among equal instants,
    /// lower `tie` pops first (and equal `(time, tie)` pops FIFO).
    pub fn push_tied(&mut self, time: f64, tie: i64, payload: T) {
        let ev = Event { time, tie, seq: self.seq, payload };
        self.seq += 1;
        self.heap.push(ev);
        self.sift_up(self.heap.len() - 1);
    }

    /// The earliest event, without removing it.
    pub fn peek(&self) -> Option<(f64, &T)> {
        self.heap.first().map(|e| (e.time, &e.payload))
    }

    /// Remove and return the earliest event.
    pub fn pop(&mut self) -> Option<(f64, T)> {
        if self.heap.is_empty() {
            return None;
        }
        let last = self.heap.len() - 1;
        self.heap.swap(0, last);
        let ev = self.heap.pop().expect("non-empty");
        if !self.heap.is_empty() {
            self.sift_down(0);
        }
        Some((ev.time, ev.payload))
    }

    fn before(a: &Event<T>, b: &Event<T>) -> bool {
        match a.time.total_cmp(&b.time) {
            Ordering::Less => true,
            Ordering::Greater => false,
            Ordering::Equal => (a.tie, a.seq) < (b.tie, b.seq),
        }
    }

    fn sift_up(&mut self, mut i: usize) {
        while i > 0 {
            let parent = (i - 1) / 2;
            if Self::before(&self.heap[i], &self.heap[parent]) {
                self.heap.swap(i, parent);
                i = parent;
            } else {
                break;
            }
        }
    }

    fn sift_down(&mut self, mut i: usize) {
        loop {
            let left = 2 * i + 1;
            if left >= self.heap.len() {
                break;
            }
            let right = left + 1;
            let child = if right < self.heap.len()
                && Self::before(&self.heap[right], &self.heap[left])
            {
                right
            } else {
                left
            };
            if Self::before(&self.heap[child], &self.heap[i]) {
                self.heap.swap(i, child);
                i = child;
            } else {
                break;
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::soc::SocSpec;

    #[test]
    fn event_queue_pops_in_time_tie_seq_order() {
        let mut q: EventQueue<&'static str> = EventQueue::new();
        q.push_tied(2.0, 0, "late");
        q.push_tied(1.0, 5, "grab"); // same instant, higher tie rank
        q.push_tied(1.0, -1, "arrive-a"); // arrivals outrank grabs
        q.push_tied(1.0, -1, "arrive-b"); // FIFO among equal keys
        q.push(0.5, "early");
        let order: Vec<&str> = std::iter::from_fn(|| q.pop().map(|(_, p)| p)).collect();
        assert_eq!(order, ["early", "arrive-a", "arrive-b", "grab", "late"]);
        assert!(q.is_empty() && q.pop().is_none());
    }

    #[test]
    fn event_queue_matches_a_stable_sort() {
        // The drop-in contract: popping reproduces `sort_by(time asc,
        // tie asc)` with insertion order preserved among equal keys.
        let mut rng = crate::util::rng::Rng::new(0xE7E27);
        for _ in 0..50 {
            let n = rng.gen_range(1, 64);
            let events: Vec<(f64, i64, usize)> = (0..n)
                .map(|i| (rng.gen_range(0, 8) as f64 * 0.25, rng.gen_range(0, 3) as i64 - 1, i))
                .collect();
            let mut q = EventQueue::with_capacity(n);
            for &(t, tie, id) in &events {
                q.push_tied(t, tie, id);
            }
            let mut sorted = events.clone();
            sorted.sort_by(|a, b| a.0.total_cmp(&b.0).then(a.1.cmp(&b.1)));
            let popped: Vec<(f64, usize)> = std::iter::from_fn(|| q.pop()).collect();
            for (got, want) in popped.iter().zip(&sorted) {
                assert_eq!(got.0, want.0);
                assert_eq!(got.1, want.2);
            }
        }
    }

    #[test]
    fn event_queue_orders_nan_last_without_panicking() {
        let mut q: EventQueue<u32> = EventQueue::new();
        q.push(f64::NAN, 0);
        q.push(1.0, 1);
        q.push(f64::INFINITY, 2);
        assert_eq!(q.pop().map(|(_, p)| p), Some(1));
        assert_eq!(q.pop().map(|(_, p)| p), Some(2));
        assert_eq!(q.pop().map(|(_, p)| p), Some(0), "NaN sorts after +inf");
    }

    #[test]
    fn run_cache_memoizes_and_counts() {
        let model = PerfModel::exynos();
        let spec = ScheduleSpec::ca_das();
        let shape = GemmShape::square(256);
        let mut cache = RunCache::new();
        let fresh = simulate(&model, &spec, shape);
        let cfg = cache.config(&model, &spec);
        assert_eq!(cfg, cache.config(&model, &spec), "interning is stable");
        let a = cache.run(&model, &spec, shape).time_s;
        assert_eq!((cache.hits(), cache.misses()), (0, 1));
        let b = cache.run(&model, &spec, shape).time_s;
        assert_eq!((cache.hits(), cache.misses()), (1, 1));
        assert_eq!(a, b);
        assert_eq!(a, fresh.time_s, "cached == fresh, bit for bit");
        assert_eq!(cache.peek(cfg, shape).expect("cached").energy.energy_j, fresh.energy.energy_j);
        assert_eq!((cache.hits(), cache.misses()), (1, 1), "peek never counts");
        assert_eq!(cache.hit_rate(), 0.5);
        assert_eq!((cache.configs(), cache.cached_runs()), (1, 1));
    }

    #[test]
    fn run_cache_distinguishes_configurations() {
        let exynos = PerfModel::exynos();
        let juno = PerfModel::new(SocSpec::juno_r0());
        let shape = GemmShape::square(192);
        let mut cache = RunCache::new();
        let a = cache.config(&exynos, &ScheduleSpec::ca_das());
        let b = cache.config(&exynos, &ScheduleSpec::sas(5.0));
        let c = cache.config(&juno, &ScheduleSpec::ca_das());
        assert!(a != b && a != c && b != c, "distinct configs, distinct ids");
        // Distinct shapes under one config are distinct runs.
        cache.run(&exynos, &ScheduleSpec::ca_das(), shape);
        cache.run(&exynos, &ScheduleSpec::ca_das(), GemmShape::square(384));
        assert_eq!(cache.misses(), 2);
        assert_eq!(cache.configs(), 3);
        // An untouched cache reports a 0 hit rate, not NaN.
        assert_eq!(RunCache::new().hit_rate(), 0.0);
    }
}
