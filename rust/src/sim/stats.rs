//! Result types for simulated GEMM runs.

use crate::blis::gemm::GemmShape;
use crate::energy::{CoreActivity, EnergyReport};

/// Everything a figure needs from one simulated run.
///
/// `PartialEq` compares every field bit for bit — the equality the
/// fast-path-vs-traced and cached-vs-fresh contracts are stated in.
#[derive(Debug, Clone, PartialEq)]
pub struct RunStats {
    pub label: String,
    pub shape: GemmShape,
    /// Virtual makespan (seconds).
    pub time_s: f64,
    /// Useful flops (2·m·n·k).
    pub flops: f64,
    pub gflops: f64,
    /// Per-core activity, indexed by global SoC core id.
    pub activity: Vec<CoreActivity>,
    /// Useful flops each cluster executed, indexed by
    /// [`crate::soc::ClusterId`]
    /// (zero for clusters the schedule left inactive). Sums to `flops`.
    /// This is the attribution the live calibration layer reads: under
    /// dynamic self-scheduling a cluster's executed-flops share reveals
    /// its relative service rate ([`crate::calibrate::live`]).
    pub cluster_flops: Vec<f64>,
    /// Total DRAM payload moved (packing, C updates, overflow streams).
    pub dram_bytes: f64,
    pub energy: EnergyReport,
    pub gflops_per_watt: f64,
    /// Dynamic-scheduling chunk grabs (0 for static).
    pub grabs: u64,
    /// Intra-cluster + global synchronization points.
    pub barriers: u64,
}

impl RunStats {
    /// Fraction of the makespan each core spent computing.
    pub fn utilization(&self) -> Vec<f64> {
        self.activity
            .iter()
            .map(|a| if self.time_s > 0.0 { a.busy_s / self.time_s } else { 0.0 })
            .collect()
    }

    /// Aggregate busy fraction over cores that did any work.
    pub fn mean_busy_utilization(&self) -> f64 {
        let used: Vec<f64> = self
            .utilization()
            .into_iter()
            .filter(|&u| u > 0.0)
            .collect();
        if used.is_empty() {
            0.0
        } else {
            used.iter().sum::<f64>() / used.len() as f64
        }
    }
}
