//! amp-gemm CLI: the leader entry point.
//!
//! Subcommands:
//! * `figures  [--fig N] [--quick] [--out DIR]` — regenerate the paper's
//!   evaluation figures (CSV + markdown + shape assertions);
//! * `search   [--core a15|a7] [--shared-kc]` — the §3.3 (mc, kc) search;
//! * `gemm     --size R [--sched S] [--backend native|sim|pjrt]` — run
//!   one GEMM;
//! * `calibrate [--report|--anchors]` — run the empirical per-OPP
//!   search, measure + persist the DES rate table and preset stores and
//!   print analytical-vs-empirical weight deltas (`--report` regenerates
//!   the calibration report; `--anchors` the model-vs-paper anchors);
//! * `trajectory [--emit F] [--baseline F] [--gate G]` — the CI
//!   perf-trajectory harness: pinned deterministic virtual-time metrics,
//!   JSON artifact, >gate regression fails the run;
//! * `serve    [--addr HOST:PORT] [--artifacts DIR]` — TCP GEMM service;
//! * `fleet    [--boards P1,P2,…] [--size R] [--batch N]` — multi-board
//!   virtual-time sweep: per-board and fleet-aggregate GFLOPS/energy
//!   under fleet-SSS/SAS/DAS (`--report` regenerates the full
//!   fleet-scaling report; `--stream` replays a Poisson-like arrival
//!   stream through the streaming dispatcher vs the wave modes);
//! * `autoscale [--quick] [--out DIR]` — SLO autoscaling report: the
//!   pinned Poisson rate sweep (elastic fleets vs the peak-sized static
//!   fleet) plus the closed-loop vs open-loop ondemand energy tables;
//! * `dvfs     [--governor G] [--size R] [--sched S]` — replay a DVFS
//!   schedule, comparing online weight retuning against stale boot
//!   weights (`--report` regenerates the OPP Pareto report;
//!   `--ladder` prints the operating-point tables);
//! * `trace    [--boards P1,P2,…] [--sizes R1,R2,…] [--requests N]
//!   [--rate RPS] [--seed S] [--out F.trace.json]` — replay a Poisson
//!   stream with tracing on and write Chrome-trace JSON (open in
//!   `ui.perfetto.dev`);
//! * `metrics  [--size R] [--json|--tsv]` — run a small pinned stream
//!   with the metrics registry enabled and print the snapshot
//!   (Prometheus text by default);
//! * `soc` — show the simulated SoC descriptor.

use amp_gemm::blis::gemm::GemmShape;
use amp_gemm::coordinator::{server, Backend, Coordinator, Request};
use amp_gemm::figures;
use amp_gemm::fleet::sim::simulate_fleet;
use amp_gemm::fleet::{Fleet, FleetStrategy};
use amp_gemm::model::PerfModel;
use amp_gemm::sched::{CoarseLoop, FineLoop, ScheduleSpec, Strategy};
use amp_gemm::search;
use amp_gemm::soc::{ClusterId, SocSpec, BIG, LITTLE};
use amp_gemm::util::cli::Args;
use amp_gemm::util::rng::Rng;
use amp_gemm::util::table::Table;
use std::path::Path;
use std::sync::Arc;

fn main() {
    let args = match Args::from_env() {
        Ok(a) => a,
        Err(e) => {
            eprintln!("argument error: {e}");
            std::process::exit(2);
        }
    };
    let cmd = args.positional.first().map(|s| s.as_str()).unwrap_or("help");
    let result = match cmd {
        "figures" => cmd_figures(&args),
        "ablation" => cmd_ablation(&args),
        "search" => cmd_search(&args),
        "gemm" => cmd_gemm(&args),
        "calibrate" => cmd_calibrate(&args),
        "trajectory" => cmd_trajectory(&args),
        "serve" => cmd_serve(&args),
        "fleet" => cmd_fleet(&args),
        "autoscale" => cmd_autoscale(&args),
        "dag" => cmd_dag(&args),
        "dvfs" => cmd_dvfs(&args),
        "trace" => cmd_trace(&args),
        "metrics" => cmd_metrics(&args),
        "soc" => cmd_soc(),
        _ => {
            print_help();
            Ok(())
        }
    };
    if let Err(e) = result {
        eprintln!("error: {e}");
        std::process::exit(1);
    }
}

fn print_help() {
    println!(
        "amp-gemm — architecture-aware GEMM scheduling on asymmetric multicores
(reproduction of Catalán et al. 2015; see DESIGN.md)

USAGE: amp-gemm <figures|search|gemm|calibrate|trajectory|serve|fleet|autoscale|dag|dvfs|trace|metrics|soc> [options]

  figures   [--fig N] [--quick] [--out results]   regenerate paper figures
  ablation  [--out results]                        §6 future-work ablations
  search    [--core a15|a7] [--shared-kc]         (mc,kc) empirical search
  gemm      --size R [--sched cadas|das|sas5|...] [--backend native|sim|pjrt]
  calibrate [--out results]   run the empirical search, measure + persist the
            per-OPP rate table and preset stores, print weight deltas
  calibrate --report [--quick] [--out results]      calibration report
  calibrate --live [--quick] [--out results]        online-calibration
            convergence report (learn rates while serving, re-plan live)
  calibrate --anchors                               model-vs-paper anchors
  trajectory [--emit BENCH_ci.json] [--baseline BENCH_baseline.json]
            [--gate 0.10] [--seed-baseline PATH]    perf-trajectory gate
  serve     [--addr 127.0.0.1:7070] [--artifacts artifacts]
  fleet     [--boards exynos5422,juno_r0] [--size R] [--batch N] [--sched sss|sas|das]
  fleet     --report [--quick] [--out results]      fixed-fleet scaling report
  fleet     --stream [--boards ...] [--sizes R1,R2,...] [--requests N]
            [--rate RPS] [--seed S]                 streaming-vs-wave sweep
  autoscale [--quick] [--out results]               SLO rate-sweep report:
            elastic fleets vs peak static, closed-loop governor energy
  dag       [--report] [--quick] [--out results]    task-DAG factorization
            report: criticality-aware vs oblivious blocked Cholesky/LU,
            mixed GEMM+factorization stream, JOB wire protocol
  dvfs      [--governor performance|powersave|ondemand[:ms]] [--size R]
            [--sched sas|casas|das|cadas] [--ladder] [--tune-opps]
            [--weights analytical|empirical|hybrid]
  dvfs      --report [--quick] [--out results]      OPP Pareto + retuning report
  trace     [--boards exynos5422,juno_r0] [--sizes R1,R2,...] [--requests N]
            [--rate RPS] [--seed S] [--out stream.trace.json]
            streamed-fleet Perfetto trace (open in ui.perfetto.dev)
  metrics   [--size R] [--json|--tsv]               metrics snapshot of a pinned
            stream (Prometheus text by default)
  soc                                              simulated SoC descriptor"
    );
}

fn cmd_figures(args: &Args) -> Result<(), String> {
    let model = PerfModel::exynos();
    let quick = args.flag("quick");
    let out = args.get_or("out", "results");
    let figs = if let Some(fig) = args.get("fig") {
        let id: usize = fig.parse().map_err(|_| format!("bad --fig '{fig}'"))?;
        vec![figures::run_figure(id, &model, quick)
            .ok_or_else(|| format!("figure {id} has no data content (diagrams: 1,2,3,6,8)"))?]
    } else {
        figures::run_all(&model, quick)
    };
    let dir = Path::new(out);
    let mut all_pass = true;
    for fig in &figs {
        println!("{}", fig.to_markdown());
        let paths = fig.write_csvs(dir).map_err(|e| e.to_string())?;
        println!(
            "wrote {} CSVs under {}\n",
            paths.len(),
            dir.display()
        );
        all_pass &= fig.passed();
    }
    if !all_pass {
        return Err("some shape assertions failed".into());
    }
    Ok(())
}

fn cmd_ablation(args: &Args) -> Result<(), String> {
    let fig = figures::ablation::run(args.flag("quick"));
    println!("{}", fig.to_markdown());
    let out = Path::new(args.get_or("out", "results"));
    let paths = fig.write_csvs(out).map_err(|e| e.to_string())?;
    println!("wrote {} CSVs under {}", paths.len(), out.display());
    if !fig.passed() {
        return Err("ablation assertions failed".into());
    }
    Ok(())
}

fn cmd_search(args: &Args) -> Result<(), String> {
    let model = PerfModel::exynos();
    let cluster = match args.get_or("core", "a15") {
        "a15" | "big" => BIG,
        "a7" | "little" => LITTLE,
        other => {
            // Accept a raw cluster index ("0", "1", …) as well.
            let idx: usize = other
                .parse()
                .map_err(|_| format!("unknown --core '{other}' (a15|a7|<cluster index>)"))?;
            if idx >= model.soc.num_clusters() {
                return Err(format!(
                    "cluster index {idx} out of range: '{}' has {} clusters",
                    model.soc.name,
                    model.soc.num_clusters()
                ));
            }
            ClusterId(idx)
        }
    };
    if args.flag("shared-kc") {
        let r = search::shared_kc_refit(&model, cluster, 952);
        println!("{}", r.to_table("shared-kc refit (kc = 952)").to_markdown());
        println!("best: mc = {} @ {:.3} GFLOPS (paper: mc = 32)", r.best.mc, r.best.gflops);
        return Ok(());
    }
    let (coarse, fine) = search::two_phase_search(&model, cluster);
    println!(
        "coarse best: (mc, kc) = ({}, {}) @ {:.3} GFLOPS",
        coarse.best.mc, coarse.best.kc, coarse.best.gflops
    );
    println!(
        "fine best:   (mc, kc) = ({}, {}) @ {:.3} GFLOPS (paper: {} )",
        fine.best.mc,
        fine.best.kc,
        fine.best.gflops,
        match cluster {
            BIG => "(152, 952)",
            LITTLE => "(80, 352)",
            _ => "n/a",
        }
    );
    Ok(())
}

fn parse_sched(s: &str) -> Result<ScheduleSpec, String> {
    let spec = match s {
        "sss" => ScheduleSpec::sss(),
        "das" => ScheduleSpec::das(),
        "cadas" | "ca-das" => ScheduleSpec::ca_das(),
        "a15" => ScheduleSpec::cluster_only(BIG, 4),
        "a7" => ScheduleSpec::cluster_only(LITTLE, 4),
        other => {
            if let Some(r) = other.strip_prefix("sas") {
                let ratio: f64 = r.parse().map_err(|_| format!("bad SAS ratio '{r}'"))?;
                ScheduleSpec::sas(ratio)
            } else if let Some(r) = other.strip_prefix("casas") {
                let ratio: f64 = r.parse().map_err(|_| format!("bad CA-SAS ratio '{r}'"))?;
                ScheduleSpec::ca_sas(ratio)
            } else {
                return Err(format!(
                    "unknown --sched '{other}' (sss|sas<r>|casas<r>|das|cadas|a15|a7)"
                ));
            }
        }
    };
    Ok(spec)
}

fn cmd_gemm(args: &Args) -> Result<(), String> {
    let r = args.usize_or("size", 512)?;
    let m = args.usize_or("m", r)?;
    let n = args.usize_or("n", r)?;
    let k = args.usize_or("k", r)?;
    let sched = parse_sched(args.get_or("sched", "cadas"))?;
    let backend = args.get_or("backend", "sim");
    let seed = args.usize_or("seed", 42)? as u64;
    let shape = GemmShape { m, n, k };

    match backend {
        "sim" => {
            let model = PerfModel::exynos();
            let st = amp_gemm::sim::simulate(&model, &sched, shape);
            println!("{}  r={m}x{n}x{k}", st.label);
            println!("  virtual time : {:.4} s", st.time_s);
            println!("  performance  : {:.3} GFLOPS", st.gflops);
            println!("  energy       : {:.3} J  ({:.3} GFLOPS/W)", st.energy.energy_j, st.gflops_per_watt);
            println!("  dram traffic : {:.1} MB", st.dram_bytes / 1e6);
            println!("  grabs/barriers: {}/{}", st.grabs, st.barriers);
        }
        "native" => {
            let soc = SocSpec::exynos5422();
            let mut rng = Rng::new(seed);
            let a = rng.fill_matrix(m * k);
            let b = rng.fill_matrix(k * n);
            let mut c = vec![0.0; m * n];
            let st = amp_gemm::native::gemm_parallel(&soc, &sched, shape, &a, &b, &mut c);
            println!("{}  r={m}x{n}x{k} (host wall-clock, not the simulated AMP)", st.label);
            println!("  wall time    : {:.4} s", st.wall_s);
            println!("  performance  : {:.3} GFLOPS (host)", st.gflops);
            println!("  checksum     : {:.6e}", c.iter().sum::<f64>());
        }
        "pjrt" => {
            let dir = Path::new(args.get_or("artifacts", "artifacts"));
            let coord = Coordinator::with_artifacts(SocSpec::exynos5422(), dir)
                .map_err(|e| e.to_string())?;
            let mut rng = Rng::new(seed);
            let a = rng.fill_matrix(m * k);
            let b = rng.fill_matrix(k * n);
            let req = Request {
                id: 1,
                shape,
                a: Arc::new(a),
                b: Arc::new(b),
                backend: Backend::Pjrt {
                    variant: args.get_or("variant", "big").to_string(),
                },
            };
            let resp = coord.execute(&req).map_err(|e| e.to_string())?;
            println!("{}  {m}x{n}x{k}", resp.backend_label);
            println!("  latency      : {:.3} ms", resp.latency_s * 1e3);
            println!("  performance  : {:.3} GFLOPS (host)", resp.gflops);
            println!("  checksum     : {:.6e}", resp.checksum);
        }
        other => return Err(format!("unknown --backend '{other}'")),
    }
    Ok(())
}

/// The calibration entry point (ISSUE 5): run the per-OPP empirical
/// search, measure the DES rate table, persist both, and print the
/// analytical-vs-empirical weight deltas. `--report` regenerates the
/// full calibration report; `--anchors` prints the original
/// model-vs-paper anchor table.
fn cmd_calibrate(args: &Args) -> Result<(), String> {
    use amp_gemm::calibrate::{RateTable, ShapeClass, WeightSource};
    use amp_gemm::search::OppPresetStore;

    if args.flag("anchors") {
        return cmd_calibrate_anchors();
    }
    if args.flag("report") {
        let fig = figures::calibrate::run(args.flag("quick"));
        println!("{}", fig.to_markdown());
        let out = Path::new(args.get_or("out", "results"));
        let paths = fig.write_csvs(out).map_err(|e| e.to_string())?;
        println!("wrote {} CSVs under {}", paths.len(), out.display());
        if !fig.passed() {
            return Err("calibration report assertions failed".into());
        }
        return Ok(());
    }
    if args.flag("live") {
        let fig = figures::live::run(args.flag("quick"));
        println!("{}", fig.to_markdown());
        let out = Path::new(args.get_or("out", "results"));
        let paths = fig.write_csvs(out).map_err(|e| e.to_string())?;
        println!("wrote {} CSVs under {}", paths.len(), out.display());
        if !fig.passed() {
            return Err("live-calibration report assertions failed".into());
        }
        return Ok(());
    }

    let soc = SocSpec::exynos5422();
    let out = Path::new(args.get_or("out", "results"));

    // 1. The per-OPP (mc, kc) search, with measured rates, persisted.
    let mut stores = Vec::new();
    for id in soc.cluster_ids() {
        let store = OppPresetStore::tune_measured(&soc, id);
        let path = out.join(format!("opp_presets_{id}.tsv"));
        store.save(&path).map_err(|e| e.to_string())?;
        let top = store.presets.last().expect("non-empty ladder");
        println!(
            "{}: searched {} rungs, nominal (mc, kc) = ({}, {}), measured {:.2} GFLOPS (large) — {}",
            soc[id].name,
            store.presets.len(),
            top.mc,
            top.kc,
            top.measured.expect("measured")[2],
            path.display()
        );
        stores.push(store);
    }

    // 2. The rate table over the searched optima, measured at the
    // evaluation suite's canonical sizes (one per shape class — the
    // same triple the calibration report asserts on) and persisted.
    let table =
        RateTable::measure_with_reps(&soc, &stores, &amp_gemm::calibrate::canonical_reps());
    let table_path = out.join("rate_table_exynos5422.tsv");
    table.save(&table_path).map_err(|e| e.to_string())?;
    println!("rate table ({} rows) — {}\n", table.rows.len(), table_path.display());

    // 3. Analytical-vs-empirical weight deltas, per shape class.
    let model = PerfModel::new(soc.clone());
    let empirical = WeightSource::Empirical(table);
    let mut t = Table::new(
        "CA-SAS weight shares: analytical vs empirical (per shape class)",
        &["class", "analytical big", "empirical big", "Δ [pp]", "analytical b:L", "empirical b:L"],
    );
    for class in ShapeClass::ALL {
        let ana = WeightSource::Analytical.weights(&model, true, class).normalized();
        let emp = empirical.weights(&model, true, class).normalized();
        t.push_row(vec![
            class.label().to_string(),
            format!("{:.4}", ana.share(0)),
            format!("{:.4}", emp.share(0)),
            format!("{:+.2}", (emp.share(0) - ana.share(0)) * 100.0),
            format!("{:.2}", ana.share(0) / ana.share(1)),
            format!("{:.2}", emp.share(0) / emp.share(1)),
        ]);
    }
    println!("{}", t.to_markdown());
    Ok(())
}

/// Perf-trajectory harness (ISSUE 5 CI satellite): collect the pinned
/// deterministic metric suite, optionally emit it as a JSON artifact,
/// and gate it against a checked-in baseline (exit non-zero past the
/// gate). `--seed-baseline` writes the collected suite as a fresh
/// baseline instead.
fn cmd_trajectory(args: &Args) -> Result<(), String> {
    use amp_gemm::calibrate::trajectory::Trajectory;

    let mut current = Trajectory::collect();
    if let Some(path) = args.get("seed-baseline") {
        // Re-seeding over an existing baseline keeps its per-entry
        // gates: the gate widths are policy (sized to each metric's
        // pinned invariant range), the values are measurement — only
        // the latter should refresh.
        if let Ok(old) = Trajectory::load(Path::new(path)) {
            let mut kept = 0;
            for e in &mut current.entries {
                if let Some(gate) = old.get(&e.key).and_then(|o| o.gate) {
                    e.gate = Some(gate);
                    kept += 1;
                }
            }
            println!("kept {kept} per-entry gates from the existing baseline");
        }
        current.save(Path::new(path)).map_err(|e| e.to_string())?;
        println!("seeded baseline with {} metrics at {path}", current.entries.len());
        return Ok(());
    }
    let mut t = Table::new(
        "perf trajectory (virtual-time, deterministic)",
        &["metric", "value", "better"],
    );
    for e in &current.entries {
        t.push_row(vec![
            e.key.clone(),
            format!("{:.6}", e.value),
            e.better.label().to_string(),
        ]);
    }
    println!("{}", t.to_markdown());
    if let Some(path) = args.get("emit") {
        current.save(Path::new(path)).map_err(|e| e.to_string())?;
        println!("emitted {} metrics to {path}", current.entries.len());
    }
    if let Some(path) = args.get("baseline") {
        let gate = args.f64_or("gate", 0.10)?;
        if !gate.is_finite() || gate <= 0.0 {
            return Err(format!("--gate must be a positive fraction, got {gate}"));
        }
        let baseline = Trajectory::load(Path::new(path))?;
        let violations = current.gate_against(&baseline, gate);
        if !violations.is_empty() {
            return Err(format!(
                "perf trajectory regressed past the gate:\n  {}",
                violations.join("\n  ")
            ));
        }
        println!(
            "gate clean: {} baseline metrics within their envelopes (default gate {:.0}%)",
            baseline.entries.len(),
            gate * 100.0
        );
    }
    Ok(())
}

fn cmd_calibrate_anchors() -> Result<(), String> {
    let model = PerfModel::exynos();
    use amp_gemm::blis::params::BlisParams;
    println!("model-vs-paper calibration anchors (see DESIGN.md §8):\n");
    println!("| anchor | paper | model |");
    println!("|---|---|---|");
    let a15 = BlisParams::a15_opt();
    let a7 = BlisParams::a7_opt();
    let r1 = model.steady_rate_gflops(BIG, &a15, 1);
    println!("| 1×A15 GFLOPS | ≈2.85 | {r1:.3} |");
    let c4 = model.cluster_rate_gflops(BIG, &a15, 4);
    println!("| 4×A15 GFLOPS | 9.6 | {c4:.3} |");
    let l1 = model.steady_rate_gflops(LITTLE, &a7, 1);
    println!("| 1×A7 GFLOPS | ≈0.6 | {l1:.3} |");
    let l4 = model.cluster_rate_gflops(LITTLE, &a7, 4);
    println!("| 4×A7 GFLOPS | ≈2.4 | {l4:.3} |");
    println!("| ideal aggregate | ≈12 | {:.3} |", c4 + l4);
    let ratio = model.ideal_ratio(&a15, &a15);
    println!("| SAS optimal ratio | 5–6 | {ratio:.2} |");
    let bad = model.cluster_rate_gflops(LITTLE, &a15, 4);
    println!("| SSS aggregate (≈2×A7-with-A15-params) | ≈40% of 9.6 | {:.3} |", 2.0 * bad);
    Ok(())
}

fn cmd_serve(args: &Args) -> Result<(), String> {
    let addr = args.get_or("addr", "127.0.0.1:7070");
    let dir = Path::new(args.get_or("artifacts", "artifacts"));
    let coord = if dir.join("manifest.txt").exists() {
        println!("loading PJRT artifacts from {}", dir.display());
        Coordinator::with_artifacts(SocSpec::exynos5422(), dir).map_err(|e| e.to_string())?
    } else {
        println!("no artifacts at {} — native/sim backends only", dir.display());
        Coordinator::new(SocSpec::exynos5422())
    };
    let handle = server::serve(Arc::new(coord), addr).map_err(|e| e.to_string())?;
    println!("serving on {} — protocol: GEMM m n k seed native|pjrt|sim ; JOB gemm|chol|lu ... ; HELP ; PING ; STATS ; METRICS ; QUIT", handle.addr);
    // Run until killed.
    loop {
        std::thread::sleep(std::time::Duration::from_secs(3600));
    }
}

/// Deterministic multi-board virtual-time sweep: shard a same-shape
/// batch across the given board presets under every fleet strategy and
/// report per-board plus fleet-aggregate GFLOPS/energy. `--report`
/// regenerates the full fleet-scaling report (tables + assertions)
/// instead.
fn cmd_fleet(args: &Args) -> Result<(), String> {
    if args.flag("stream") {
        if args.flag("report") {
            return Err("--stream and --report are separate modes; pick one".into());
        }
        return cmd_fleet_stream(args);
    }
    if args.flag("report") {
        // The report runs a fixed fleet/shape matrix (its assertions are
        // calibrated to them); the sweep flags apply to the ad-hoc mode.
        for flag in ["boards", "size", "batch", "m", "n", "k", "sched"] {
            if args.get(flag).is_some() {
                return Err(format!(
                    "--{flag} does not combine with --report (the report's \
                     fleet and shape are fixed); drop --report for an ad-hoc sweep"
                ));
            }
        }
        let fig = figures::fleet::run(args.flag("quick"));
        println!("{}", fig.to_markdown());
        let out = Path::new(args.get_or("out", "results"));
        let paths = fig.write_csvs(out).map_err(|e| e.to_string())?;
        println!("wrote {} CSVs under {}", paths.len(), out.display());
        if !fig.passed() {
            return Err("fleet report assertions failed".into());
        }
        return Ok(());
    }

    let fleet = Fleet::parse(args.get_or("boards", "exynos5422,juno_r0"))?;
    let r = args.usize_or("size", 2048)?;
    let m = args.usize_or("m", r)?;
    let n = args.usize_or("n", r)?;
    let k = args.usize_or("k", r)?;
    let batch = args.usize_or("batch", 32)?;
    if batch == 0 {
        return Err("--batch must be at least 1".into());
    }
    let shape = GemmShape { m, n, k };

    println!(
        "fleet of {} boards, {}x{}x{} × {batch} items (virtual time)\n",
        fleet.num_boards(),
        m,
        n,
        k
    );
    let strategies = match args.get("sched") {
        Some(s) => vec![FleetStrategy::parse(s)?],
        None => vec![FleetStrategy::Sss, FleetStrategy::Sas, FleetStrategy::Das],
    };
    for strategy in strategies {
        let st = simulate_fleet(&fleet, strategy, shape, batch);
        let mut table = Table::new(
            &format!(
                "{} — makespan {:.3} s, {:.2} GFLOPS, {:.2} req/s, {:.1} J, {:.3} GFLOPS/W",
                st.label, st.makespan_s, st.gflops, st.throughput_rps, st.energy_j,
                st.gflops_per_watt
            ),
            &["board", "items", "grabs", "busy [s]", "finish [s]", "GFLOPS", "energy [J]"],
        );
        for b in &st.boards {
            table.push_row(vec![
                b.name.clone(),
                b.items.to_string(),
                b.grabs.to_string(),
                format!("{:.3}", b.busy_s),
                format!("{:.3}", b.finish_s),
                format!("{:.2}", b.gflops),
                format!("{:.1}", b.energy_j),
            ]);
        }
        println!("{}", table.to_markdown());
    }
    Ok(())
}

/// Streaming sweep (ISSUE 4): replay a deterministic Poisson-like
/// arrival stream of mixed square shapes over the fleet — once per
/// wave-mode strategy (today's synchronous one-wave-per-batch
/// discipline) and once through the streaming dispatcher — and report
/// makespan, utilization and queue-depth side by side, plus the
/// stream's per-board breakdown.
fn cmd_fleet_stream(args: &Args) -> Result<(), String> {
    use amp_gemm::fleet::sim::poisson_arrivals;

    let fleet = Fleet::parse(args.get_or("boards", "exynos5422,juno_r0"))?;
    let sizes = args
        .usize_list("sizes")?
        .unwrap_or_else(|| vec![384, 512, 640]);
    if sizes.iter().any(|&r| r == 0) {
        return Err("--sizes entries must be at least 1".into());
    }
    let count = args.usize_or("requests", 32)?;
    if count == 0 {
        return Err("--requests must be at least 1".into());
    }
    let rate = args.f64_or("rate", 80.0)?;
    if !rate.is_finite() || rate <= 0.0 {
        return Err(format!("--rate must be a positive request rate, got {rate}"));
    }
    let seed = args.usize_or("seed", 42)? as u64;

    let shapes: Vec<GemmShape> = sizes.iter().map(|&r| GemmShape::square(r)).collect();
    let mut rng = Rng::new(seed);
    let arrivals = poisson_arrivals(&mut rng, &shapes, count, rate);
    println!(
        "streaming {count} requests over {} boards — sizes {sizes:?}, \
         rate {rate:.1} req/s, seed {seed} (virtual time)\n",
        fleet.num_boards()
    );

    let (table, _, stream) = figures::fleet::stream_table(
        &format!(
            "streaming vs wave dispatch — {} requests, last arrival {:.3} s",
            count,
            arrivals.last().expect("non-empty").arrive_s
        ),
        &fleet,
        &arrivals,
    );
    println!("{}", table.to_markdown());

    let mut boards = Table::new(
        &format!("{} — per-board breakdown", stream.label),
        &[
            "board", "items", "grabs", "busy [s]", "finish [s]", "idle tail [s]", "util",
            "energy [J]",
        ],
    );
    for b in &stream.boards {
        boards.push_row(vec![
            b.name.clone(),
            b.items.to_string(),
            b.grabs.to_string(),
            format!("{:.3}", b.busy_s),
            format!("{:.3}", b.finish_s),
            format!("{:.3}", b.idle_tail_s),
            format!("{:.3}", b.utilization),
            format!("{:.1}", b.energy_j),
        ]);
    }
    println!("{}", boards.to_markdown());
    Ok(())
}

/// `amp-gemm autoscale` (ISSUE 8): regenerate the SLO autoscaling +
/// closed-loop governor report — the pinned Poisson rate sweep past
/// saturation (elastic vs peak-sized static provisioning) and the
/// load-driven vs time-ramp ondemand energy comparison.
fn cmd_autoscale(args: &Args) -> Result<(), String> {
    let fig = figures::autoscale::run(args.flag("quick"));
    println!("{}", fig.to_markdown());
    let out = Path::new(args.get_or("out", "results"));
    let paths = fig.write_csvs(out).map_err(|e| e.to_string())?;
    println!("wrote {} CSVs under {}", paths.len(), out.display());
    if !fig.passed() {
        return Err("autoscale report assertions failed".into());
    }
    Ok(())
}

/// Task-DAG factorization report (ISSUE 10): criticality-aware vs
/// cluster-oblivious blocked Cholesky/LU schedules, the mixed-job
/// stream through the unified JobSpec DES, and the JOB wire protocol.
/// `--report` is accepted for symmetry with the other report commands
/// but is the only mode.
fn cmd_dag(args: &Args) -> Result<(), String> {
    let fig = figures::dag::run(args.flag("quick"));
    println!("{}", fig.to_markdown());
    let out = Path::new(args.get_or("out", "results"));
    let paths = fig.write_csvs(out).map_err(|e| e.to_string())?;
    println!("wrote {} CSVs under {}", paths.len(), out.display());
    if !fig.passed() {
        return Err("dag report assertions failed".into());
    }
    Ok(())
}

/// Replay a DVFS schedule on the Exynos descriptor: print the OPP
/// ladders, then compare SAS with online weight retuning against the
/// stale boot-time split under the chosen governor. `--report`
/// regenerates the full Pareto/retuning report instead; `--tune-opps`
/// runs the §3.3 search at every ladder rung and persists the per-point
/// presets.
fn cmd_dvfs(args: &Args) -> Result<(), String> {
    use amp_gemm::dvfs::sim::{simulate_dvfs_with, DvfsStrategy, Retune};
    use amp_gemm::dvfs::{parse_governor, Governor};

    if args.flag("report") {
        let fig = figures::dvfs::run(args.flag("quick"));
        println!("{}", fig.to_markdown());
        let out = Path::new(args.get_or("out", "results"));
        let paths = fig.write_csvs(out).map_err(|e| e.to_string())?;
        println!("wrote {} CSVs under {}", paths.len(), out.display());
        if !fig.passed() {
            return Err("dvfs report assertions failed".into());
        }
        return Ok(());
    }

    let soc = SocSpec::exynos5422();
    if args.flag("ladder") {
        for id in soc.cluster_ids() {
            let cl = &soc[id];
            let mut t = Table::new(
                &format!("{} OPP ladder (nominal = rung {})", cl.name, cl.opps.nominal_idx()),
                &["opp", "GHz", "V", "power scale"],
            );
            for o in 0..cl.opps.len() {
                let p = cl.opps.get(o);
                t.push_row(vec![
                    o.to_string(),
                    format!("{:.2}", p.freq_ghz),
                    format!("{:.4}", p.volt_v),
                    format!("{:.3}", cl.opps.power_scale(o)),
                ]);
            }
            println!("{}", t.to_markdown());
        }
        return Ok(());
    }

    if args.flag("tune-opps") {
        let out = Path::new(args.get_or("out", "results"));
        for id in soc.cluster_ids() {
            let store = search::OppPresetStore::tune(&soc, id);
            let path = out.join(format!("opp_presets_{id}.tsv"));
            store.save(&path).map_err(|e| e.to_string())?;
            println!(
                "{}: tuned {} rungs, best (mc, kc) = ({}, {}) at nominal — saved {}",
                soc[id].name,
                store.presets.len(),
                store.presets.last().unwrap().mc,
                store.presets.last().unwrap().kc,
                path.display()
            );
        }
        return Ok(());
    }

    let gov = parse_governor(args.get_or("governor", "ondemand"))?;
    let r = args.usize_or("size", 2048)?;
    let shape = GemmShape::square(r);
    let strat = match args.get_or("sched", "casas") {
        "sas" => DvfsStrategy::Sas { cache_aware: false },
        "casas" | "ca-sas" => DvfsStrategy::Sas { cache_aware: true },
        "das" => DvfsStrategy::Das { cache_aware: false },
        "cadas" | "ca-das" => DvfsStrategy::Das { cache_aware: true },
        other => return Err(format!("unknown --sched '{other}' (sas|casas|das|cadas)")),
    };
    // Where the SAS weight vector comes from: the analytical model, or
    // a freshly measured rate table (ISSUE 5 — the calibration layer's
    // per-OPP rates feeding the online retuner).
    let source = amp_gemm::calibrate::WeightSource::from_token(
        args.get_or("weights", "analytical"),
        || amp_gemm::calibrate::RateTable::measure(&soc, &[]),
    )?;
    let plan = gov.plan(&soc, 1e3);
    println!(
        "{} governor on {}: {} transitions planned ({} weights)\n",
        gov.name(),
        soc.name,
        plan.transitions.len(),
        source.label()
    );
    let mut t = Table::new(
        &format!("{} under the {} governor, r = {r}", strat.label(), gov.name()),
        &["weights", "makespan [s]", "GFLOPS", "energy [J]", "GFLOPS/W", "retunes", "transitions"],
    );
    for retune in [Retune::Boot, Retune::Online] {
        let st = simulate_dvfs_with(&soc, strat, shape, &plan, retune, &source);
        t.push_row(vec![
            retune.label().to_string(),
            format!("{:.3}", st.time_s),
            format!("{:.2}", st.gflops),
            format!("{:.1}", st.energy_j),
            format!("{:.3}", st.gflops_per_watt),
            st.retunes.to_string(),
            st.transitions_applied.to_string(),
        ]);
    }
    println!("{}", t.to_markdown());
    Ok(())
}

/// `amp-gemm trace`: replay a Poisson stream with tracing on and write
/// Chrome-trace JSON (the Perfetto-openable artifact; also the CI
/// smoke target, validated by `python3 -m json.tool`).
fn cmd_trace(args: &Args) -> Result<(), String> {
    use amp_gemm::fleet::sim::{poisson_arrivals, simulate_fleet_stream_traced};
    use amp_gemm::obs::{trace, MemorySink, MetricsRegistry};
    use amp_gemm::sim::RunCache;

    let fleet = Fleet::parse(args.get_or("boards", "exynos5422,juno_r0"))?;
    let sizes = args
        .usize_list("sizes")?
        .unwrap_or_else(|| vec![384, 512, 640]);
    if sizes.iter().any(|&r| r == 0) {
        return Err("--sizes entries must be at least 1".into());
    }
    let count = args.usize_or("requests", 24)?;
    if count == 0 {
        return Err("--requests must be at least 1".into());
    }
    let rate = args.f64_or("rate", 80.0)?;
    if !rate.is_finite() || rate <= 0.0 {
        return Err(format!("--rate must be a positive request rate, got {rate}"));
    }
    let seed = args.usize_or("seed", 42)? as u64;
    let out = args.get_or("out", "stream.trace.json");

    let shapes: Vec<GemmShape> = sizes.iter().map(|&r| GemmShape::square(r)).collect();
    let mut rng = Rng::new(seed);
    let arrivals = poisson_arrivals(&mut rng, &shapes, count, rate);
    let mut cache = RunCache::new();
    let mut sink = MemorySink::new();
    let mut metrics = MetricsRegistry::new();
    let stats =
        simulate_fleet_stream_traced(&fleet, &arrivals, &mut cache, &mut sink, &mut metrics);
    let doc = sink.to_chrome_json();
    let n_events = trace::validate_chrome_json(&doc)?;
    std::fs::write(out, &doc).map_err(|e| e.to_string())?;
    println!(
        "traced {} requests over {} boards: {n_events} events -> {out}\n\
         makespan {:.3} s, sojourn p50 {:.3} s / p99 {:.3} s — open in ui.perfetto.dev",
        stats.requests,
        fleet.num_boards(),
        stats.makespan_s,
        stats.sojourn_p50_s,
        stats.sojourn_p99_s
    );
    Ok(())
}

/// `amp-gemm metrics`: run a small pinned stream with the registry
/// enabled and print the snapshot (Prometheus text exposition by
/// default; `--json` for the one-line snapshot the coordinator
/// `METRICS` command also serves; `--tsv` for the exact round-trip
/// form).
fn cmd_metrics(args: &Args) -> Result<(), String> {
    use amp_gemm::fleet::sim::{poisson_arrivals, simulate_fleet_stream_traced};
    use amp_gemm::obs::{MetricsRegistry, NullSink};
    use amp_gemm::sim::{simulate, RunCache};

    let size = args.usize_or("size", 512)?;
    if size == 0 {
        return Err("--size must be at least 1".into());
    }
    let fleet = Fleet::parse(args.get_or("boards", "exynos5422,juno_r0"))?;
    let shapes = vec![GemmShape::square(size)];
    let mut rng = Rng::new(args.usize_or("seed", 42)? as u64);
    let arrivals = poisson_arrivals(&mut rng, &shapes, 16, 80.0);
    let mut cache = RunCache::new();
    let mut metrics = MetricsRegistry::new();
    let stats =
        simulate_fleet_stream_traced(&fleet, &arrivals, &mut cache, &mut NullSink, &mut metrics);
    metrics.set_gauge("stream_makespan_s", stats.makespan_s);
    // Per-cluster rails of one item on board 0 — the energy layer's
    // registry hook, exercised end to end.
    let item = simulate(fleet.boards[0].model(), &fleet.boards[0].sched, shapes[0]);
    item.energy.export_metrics(&mut metrics, "board0_item");
    if args.flag("json") {
        println!("{}", metrics.to_json());
    } else if args.flag("tsv") {
        print!("{}", metrics.to_tsv());
    } else {
        print!("{}", metrics.to_prometheus());
    }
    Ok(())
}

fn cmd_soc() -> Result<(), String> {
    for soc in [
        SocSpec::exynos5422(),
        SocSpec::dynamiq_3c(),
        SocSpec::symmetric(4),
    ] {
        println!("{}", soc.name);
        for id in soc.cluster_ids() {
            let cl = &soc[id];
            println!(
                "  {id} {} × {} ({}): {:.1} GHz, L1d {} KiB, shared L2 {} KiB, \
                 peak {:.2} GFLOPS/core, tuned (mc, kc) = ({}, {})",
                cl.num_cores,
                cl.name,
                cl.short_name,
                cl.core.freq_ghz,
                cl.core.l1d.size_bytes / 1024,
                cl.l2.size_bytes / 1024,
                cl.core.peak_gflops(),
                cl.tuned.mc,
                cl.tuned.kc,
            );
        }
        println!(
            "  DRAM: {:.1} GB/s, {} MiB\n",
            soc.dram_bw_gbs,
            soc.dram_total_bytes / (1 << 20)
        );
    }
    let _ = Strategy::Sss; // referenced for doc completeness
    let _ = (CoarseLoop::Loop1, FineLoop::Loop4);
    Ok(())
}
