//! SoC descriptors for asymmetric multicore processors.
//!
//! The paper's testbed is the Samsung Exynos 5422 (ODROID-XU3): an ARM
//! big.LITTLE SoC with a quad-core Cortex-A15 ("big") cluster @ 1.6 GHz
//! sharing a 2 MiB L2, and a quad-core Cortex-A7 ("LITTLE") cluster
//! @ 1.4 GHz sharing a 512 KiB L2; every core has a private 32+32 KiB L1
//! and both clusters see a shared DDR3 through coherent 128-bit buses
//! (paper §3.2, Fig. 3). Since that hardware is not available here, this
//! module is the authoritative *descriptor* the simulator, cache model,
//! perf model and energy model all consume (DESIGN.md §1).
//!
//! A generic builder supports the paper's future-work ablations
//! (different big/LITTLE core counts, ARMv8-class cache sizes).

/// Which of the two asymmetric core types a core belongs to.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub enum CoreType {
    /// Fast, out-of-order core (Cortex-A15 in the paper).
    Big,
    /// Slow, in-order, low-power core (Cortex-A7).
    Little,
}

impl CoreType {
    pub const ALL: [CoreType; 2] = [CoreType::Big, CoreType::Little];

    pub fn name(self) -> &'static str {
        match self {
            CoreType::Big => "Cortex-A15",
            CoreType::Little => "Cortex-A7",
        }
    }

    pub fn short(self) -> &'static str {
        match self {
            CoreType::Big => "big",
            CoreType::Little => "LITTLE",
        }
    }

    pub fn other(self) -> CoreType {
        match self {
            CoreType::Big => CoreType::Little,
            CoreType::Little => CoreType::Big,
        }
    }
}

/// Geometry of one cache level.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct CacheGeometry {
    pub size_bytes: usize,
    pub associativity: usize,
    pub line_bytes: usize,
}

impl CacheGeometry {
    pub fn new(size_bytes: usize, associativity: usize, line_bytes: usize) -> Self {
        let g = CacheGeometry {
            size_bytes,
            associativity,
            line_bytes,
        };
        g.validate();
        g
    }

    pub fn num_sets(&self) -> usize {
        self.size_bytes / (self.associativity * self.line_bytes)
    }

    pub fn validate(&self) {
        assert!(self.line_bytes.is_power_of_two(), "line size must be 2^k");
        assert!(self.associativity >= 1);
        assert_eq!(
            self.size_bytes % (self.associativity * self.line_bytes),
            0,
            "cache size must be sets*ways*line"
        );
        assert!(self.num_sets().is_power_of_two(), "set count must be 2^k");
    }
}

/// Per-core-type microarchitectural description.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct CoreSpec {
    pub core_type: CoreType,
    pub freq_ghz: f64,
    /// Private L1 data cache.
    pub l1d: CacheGeometry,
    /// Double-precision flops/cycle the FPU can retire from the
    /// micro-kernel's rank-1 update sequence under ideal conditions.
    /// (A15: NEON-VFPv4 FMA pipe; A7: simpler in-order VFP.)
    pub dp_flops_per_cycle: f64,
}

impl CoreSpec {
    /// Ideal peak double-precision GFLOPS of one core.
    pub fn peak_gflops(&self) -> f64 {
        self.freq_ghz * self.dp_flops_per_cycle
    }
}

/// A cluster: n identical cores sharing one L2.
#[derive(Debug, Clone, PartialEq)]
pub struct ClusterSpec {
    pub core: CoreSpec,
    pub num_cores: usize,
    /// Shared, unified L2 cache of the cluster.
    pub l2: CacheGeometry,
}

/// Whole-SoC description.
#[derive(Debug, Clone, PartialEq)]
pub struct SocSpec {
    pub name: String,
    pub big: ClusterSpec,
    pub little: ClusterSpec,
    /// Sustained DRAM bandwidth observable by one cluster (GB/s).
    pub dram_bw_gbs: f64,
    pub dram_total_bytes: usize,
}

impl SocSpec {
    /// The paper's testbed (§3.2, Fig. 3).
    pub fn exynos5422() -> SocSpec {
        SocSpec {
            name: "Samsung Exynos 5422 (ODROID-XU3)".to_string(),
            big: ClusterSpec {
                core: CoreSpec {
                    core_type: CoreType::Big,
                    freq_ghz: 1.6,
                    l1d: CacheGeometry::new(32 * 1024, 2, 64),
                    // Calibrated so the modelled single-core optimum lands
                    // at the paper's ~2.85 GFLOPS (model/calibration.rs).
                    dp_flops_per_cycle: 2.0,
                },
                num_cores: 4,
                l2: CacheGeometry::new(2 * 1024 * 1024, 16, 64),
            },
            little: ClusterSpec {
                core: CoreSpec {
                    core_type: CoreType::Little,
                    freq_ghz: 1.4,
                    l1d: CacheGeometry::new(32 * 1024, 4, 64),
                    dp_flops_per_cycle: 0.5,
                },
                num_cores: 4,
                l2: CacheGeometry::new(512 * 1024, 8, 64),
            },
            dram_bw_gbs: 3.2,
            dram_total_bytes: 2 * 1024 * 1024 * 1024,
        }
    }

    /// Generic big.LITTLE-style SoC for ablation studies (paper §6
    /// future work: "architectures with different number of big/LITTLE
    /// cores"). Scales the Exynos descriptor's core counts.
    pub fn custom_counts(num_big: usize, num_little: usize) -> SocSpec {
        assert!(num_big >= 1 && num_little >= 1);
        let mut soc = SocSpec::exynos5422();
        soc.name = format!("custom big.LITTLE {num_big}+{num_little}");
        soc.big.num_cores = num_big;
        soc.little.num_cores = num_little;
        soc
    }

    /// DVFS variant: same silicon, different operating points (§5.2:
    /// the SAS ratio knob exists precisely because "changes in the core
    /// frequency ... affect the performance ratio between core types").
    pub fn with_freqs(mut self, big_ghz: f64, little_ghz: f64) -> SocSpec {
        assert!(big_ghz > 0.0 && little_ghz > 0.0);
        self.name = format!("{} @ {big_ghz}/{little_ghz} GHz", self.name);
        self.big.core.freq_ghz = big_ghz;
        self.little.core.freq_ghz = little_ghz;
        self
    }

    /// ARM Juno r0 development board — the paper's §6 "port to a 64-bit
    /// ARMv8 architecture" roadmap item: 2× Cortex-A57 @ 1.1 GHz with a
    /// 2 MiB shared L2, plus 4× Cortex-A53 @ 850 MHz with a 1 MiB L2.
    /// The A57's wider NEON datapath retires more dp flops per cycle.
    pub fn juno_r0() -> SocSpec {
        SocSpec {
            name: "ARM Juno r0 (ARMv8: 2×A57 + 4×A53)".to_string(),
            big: ClusterSpec {
                core: CoreSpec {
                    core_type: CoreType::Big,
                    freq_ghz: 1.1,
                    l1d: CacheGeometry::new(32 * 1024, 2, 64),
                    dp_flops_per_cycle: 4.0,
                },
                num_cores: 2,
                l2: CacheGeometry::new(2 * 1024 * 1024, 16, 64),
            },
            little: ClusterSpec {
                core: CoreSpec {
                    core_type: CoreType::Little,
                    freq_ghz: 0.85,
                    l1d: CacheGeometry::new(32 * 1024, 4, 64),
                    dp_flops_per_cycle: 1.0,
                },
                num_cores: 4,
                l2: CacheGeometry::new(1024 * 1024, 16, 64),
            },
            dram_bw_gbs: 5.0,
            dram_total_bytes: 8 * 1024 * 1024 * 1024,
        }
    }

    pub fn cluster(&self, t: CoreType) -> &ClusterSpec {
        match t {
            CoreType::Big => &self.big,
            CoreType::Little => &self.little,
        }
    }

    pub fn total_cores(&self) -> usize {
        self.big.num_cores + self.little.num_cores
    }

    /// Global core id range for a cluster: big cores come first
    /// ([0, nb)), then LITTLE ([nb, nb+nl)). The simulator, native
    /// executor and energy meter all share this numbering.
    pub fn core_ids(&self, t: CoreType) -> std::ops::Range<usize> {
        match t {
            CoreType::Big => 0..self.big.num_cores,
            CoreType::Little => self.big.num_cores..self.total_cores(),
        }
    }

    pub fn core_type_of(&self, core_id: usize) -> CoreType {
        assert!(core_id < self.total_cores(), "core id {core_id} out of range");
        if core_id < self.big.num_cores {
            CoreType::Big
        } else {
            CoreType::Little
        }
    }

    /// Ideal aggregate peak (sum of single-core peaks) — upper bound
    /// reference only; the perf model applies efficiency + contention.
    pub fn aggregate_peak_gflops(&self) -> f64 {
        self.big.core.peak_gflops() * self.big.num_cores as f64
            + self.little.core.peak_gflops() * self.little.num_cores as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn exynos_matches_paper_spec() {
        let soc = SocSpec::exynos5422();
        assert_eq!(soc.big.num_cores, 4);
        assert_eq!(soc.little.num_cores, 4);
        assert_eq!(soc.big.core.freq_ghz, 1.6);
        assert_eq!(soc.little.core.freq_ghz, 1.4);
        assert_eq!(soc.big.l2.size_bytes, 2 * 1024 * 1024);
        assert_eq!(soc.little.l2.size_bytes, 512 * 1024);
        assert_eq!(soc.big.core.l1d.size_bytes, 32 * 1024);
        assert_eq!(soc.little.core.l1d.size_bytes, 32 * 1024);
    }

    #[test]
    fn l2_ratio_is_four() {
        let soc = SocSpec::exynos5422();
        assert_eq!(soc.big.l2.size_bytes / soc.little.l2.size_bytes, 4);
    }

    #[test]
    fn core_id_mapping_round_trips() {
        let soc = SocSpec::exynos5422();
        for id in soc.core_ids(CoreType::Big) {
            assert_eq!(soc.core_type_of(id), CoreType::Big);
        }
        for id in soc.core_ids(CoreType::Little) {
            assert_eq!(soc.core_type_of(id), CoreType::Little);
        }
        assert_eq!(soc.total_cores(), 8);
    }

    #[test]
    #[should_panic(expected = "out of range")]
    fn core_type_of_out_of_range_panics() {
        SocSpec::exynos5422().core_type_of(8);
    }

    #[test]
    fn cache_geometry_sets() {
        let g = CacheGeometry::new(32 * 1024, 2, 64);
        assert_eq!(g.num_sets(), 256);
    }

    #[test]
    #[should_panic]
    fn bad_cache_geometry_rejected() {
        CacheGeometry::new(33 * 1024, 2, 64);
    }

    #[test]
    fn big_cores_faster_than_little() {
        let soc = SocSpec::exynos5422();
        assert!(soc.big.core.peak_gflops() > 3.0 * soc.little.core.peak_gflops());
    }

    #[test]
    fn custom_counts_builder() {
        let soc = SocSpec::custom_counts(2, 6);
        assert_eq!(soc.total_cores(), 8);
        assert_eq!(soc.core_ids(CoreType::Little), 2..8);
    }

    #[test]
    fn core_type_helpers() {
        assert_eq!(CoreType::Big.other(), CoreType::Little);
        assert_eq!(CoreType::Big.name(), "Cortex-A15");
        assert_eq!(CoreType::Little.short(), "LITTLE");
    }

    #[test]
    fn aggregate_peak_positive() {
        assert!(SocSpec::exynos5422().aggregate_peak_gflops() > 10.0);
    }
}
