//! SoC descriptors for asymmetric multicore processors.
//!
//! The paper's testbed is the Samsung Exynos 5422 (ODROID-XU3): an ARM
//! big.LITTLE SoC with a quad-core Cortex-A15 ("big") cluster @ 1.6 GHz
//! sharing a 2 MiB L2, and a quad-core Cortex-A7 ("LITTLE") cluster
//! @ 1.4 GHz sharing a 512 KiB L2; every core has a private 32+32 KiB L1
//! and both clusters see a shared DDR3 through coherent 128-bit buses
//! (paper §3.2, Fig. 3). Since that hardware is not available here, this
//! module is the authoritative *descriptor* the simulator, cache model,
//! perf model and energy model all consume (DESIGN.md §1).
//!
//! # The N-cluster `Topology` model
//!
//! The descriptor is *not* limited to two clusters: a [`SocSpec`] holds a
//! `Vec<ClusterSpec>` and every consumer (schedulers, partitioners, the
//! DES simulator, the native executor, the energy meter) iterates over
//! clusters addressed by [`ClusterId`] instead of branching on a
//! big/LITTLE enum. This is what lets the same scheduling code run on
//! the paper's Exynos 5422, a tri-cluster DynamIQ-style SoC
//! ([`SocSpec::dynamiq_3c`]), a symmetric SMP ([`SocSpec::symmetric`])
//! and ARMv8 boards ([`SocSpec::juno_r0`]) without modification
//! (DESIGN.md §2).
//!
//! Each [`ClusterSpec`] carries everything that used to be keyed on the
//! core *type*: core count, frequency, cache geometry, flops/cycle, the
//! tuned BLIS blocking parameters, and the calibrated per-cluster model
//! constants ([`ClusterTuning`]: amortization, contention, packing
//! bandwidth, synchronization costs and power rails).
//!
//! Conventions:
//! * clusters are ordered fastest-first in the presets; [`BIG`] and
//!   [`LITTLE`] name indices 0 and 1 for two-cluster code and tests;
//! * global core ids are contiguous per cluster, cluster 0 first —
//!   the simulator, native executor and energy meter all share this
//!   numbering ([`SocSpec::core_ids`]).

use crate::blis::params::BlisParams;

/// Index of a cluster within a [`SocSpec`]. Cores are addressed as
/// `(ClusterId, core_idx)`; [`SocSpec::core_ids`] maps a cluster to its
/// global core-id range.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct ClusterId(pub usize);

impl ClusterId {
    /// Stable short label ("c0", "c1", …) for tables and traces that
    /// have no [`SocSpec`] at hand.
    pub fn label(self) -> String {
        format!("c{}", self.0)
    }
}

impl std::fmt::Display for ClusterId {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "c{}", self.0)
    }
}

/// Conventional index of the fast cluster in two-cluster presets.
pub const BIG: ClusterId = ClusterId(0);
/// Conventional index of the slow cluster in two-cluster presets.
pub const LITTLE: ClusterId = ClusterId(1);

/// Geometry of one cache level.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct CacheGeometry {
    pub size_bytes: usize,
    pub associativity: usize,
    pub line_bytes: usize,
}

impl CacheGeometry {
    pub fn new(size_bytes: usize, associativity: usize, line_bytes: usize) -> Self {
        let g = CacheGeometry {
            size_bytes,
            associativity,
            line_bytes,
        };
        g.validate();
        g
    }

    pub fn num_sets(&self) -> usize {
        self.size_bytes / (self.associativity * self.line_bytes)
    }

    pub fn validate(&self) {
        assert!(self.line_bytes.is_power_of_two(), "line size must be 2^k");
        assert!(self.associativity >= 1);
        assert_eq!(
            self.size_bytes % (self.associativity * self.line_bytes),
            0,
            "cache size must be sets*ways*line"
        );
        assert!(self.num_sets().is_power_of_two(), "set count must be 2^k");
    }
}

/// One DVFS operating point of a cluster: a frequency/voltage pair from
/// the cluster's OPP ladder (the `cpufreq` table of the real SoC).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct OperatingPoint {
    pub freq_ghz: f64,
    pub volt_v: f64,
}

impl OperatingPoint {
    pub fn new(freq_ghz: f64, volt_v: f64) -> Self {
        assert!(
            freq_ghz.is_finite() && freq_ghz > 0.0 && volt_v.is_finite() && volt_v > 0.0,
            "operating point must have positive finite frequency and voltage \
             ({freq_ghz} GHz, {volt_v} V)"
        );
        OperatingPoint { freq_ghz, volt_v }
    }
}

/// A cluster's DVFS ladder: operating points in strictly ascending
/// frequency (and non-decreasing voltage) order. The *last* entry is the
/// nominal point every preset boots at — for the paper presets it is
/// exactly the §3.2 frequency, so a schedule pinned at the nominal OPP
/// is bit-for-bit the original descriptor.
///
/// Dynamic power at point `i` scales as `(f/f_nom)·(V/V_nom)²` relative
/// to nominal ([`OppTable::power_scale`]) — the CMOS `f·V²` law the
/// energy follow-up (arXiv:1507.05129) exploits.
#[derive(Debug, Clone, PartialEq)]
pub struct OppTable {
    points: Vec<OperatingPoint>,
    /// Rung the owning descriptor is currently derived at. Presets boot
    /// at the nominal rung; [`SocSpec::at_opp`] moves it, so derivation
    /// is *absolute* — re-deriving an already-derived descriptor never
    /// compounds the rail scaling.
    cur: usize,
}

impl OppTable {
    pub fn new(points: Vec<OperatingPoint>) -> Self {
        assert!(!points.is_empty(), "an OPP ladder needs at least one point");
        for w in points.windows(2) {
            assert!(
                w[0].freq_ghz < w[1].freq_ghz && w[0].volt_v <= w[1].volt_v,
                "OPP ladder must ascend in frequency and voltage: {points:?}"
            );
        }
        let cur = points.len() - 1;
        OppTable { points, cur }
    }

    /// Degenerate single-point ladder (no DVFS): the nominal frequency
    /// at a reference 1.0 V.
    pub fn single(freq_ghz: f64) -> Self {
        OppTable::new(vec![OperatingPoint::new(freq_ghz, 1.0)])
    }

    /// Exynos 5422 Cortex-A15 ladder, capped at the paper's §3.2
    /// operating point (1.6 GHz): the `cpufreq` steps the testbed's
    /// governor walks, with the A15 rail's voltage schedule.
    pub fn a15() -> Self {
        OppTable::new(vec![
            OperatingPoint::new(0.8, 0.9000),
            OperatingPoint::new(1.0, 0.9500),
            OperatingPoint::new(1.2, 1.0125),
            OperatingPoint::new(1.4, 1.0875),
            OperatingPoint::new(1.6, 1.1625),
        ])
    }

    /// Exynos 5422 Cortex-A7 ladder, topping out at the paper's 1.4 GHz.
    pub fn a7() -> Self {
        OppTable::new(vec![
            OperatingPoint::new(0.5, 0.9000),
            OperatingPoint::new(0.8, 0.9500),
            OperatingPoint::new(1.0, 1.0000),
            OperatingPoint::new(1.2, 1.0500),
            OperatingPoint::new(1.4, 1.1375),
        ])
    }

    /// Generic five-step ladder for non-Exynos presets: 50/65/80/90/100 %
    /// of the nominal frequency with a typical voltage schedule.
    pub fn generic(nominal_ghz: f64) -> Self {
        assert!(nominal_ghz.is_finite() && nominal_ghz > 0.0);
        let steps = [(0.50, 0.90), (0.65, 0.95), (0.80, 1.00), (0.90, 1.06), (1.00, 1.13)];
        OppTable::new(
            steps
                .iter()
                .map(|&(f, v)| OperatingPoint::new(nominal_ghz * f, v))
                .collect(),
        )
    }

    pub fn len(&self) -> usize {
        self.points.len()
    }

    pub fn is_empty(&self) -> bool {
        self.points.is_empty()
    }

    pub fn get(&self, idx: usize) -> OperatingPoint {
        self.points[idx]
    }

    pub fn points(&self) -> &[OperatingPoint] {
        &self.points
    }

    /// Index of the nominal (boot) point: the ladder top.
    pub fn nominal_idx(&self) -> usize {
        self.points.len() - 1
    }

    /// Rung the owning descriptor is currently derived at (the nominal
    /// rung for freshly built presets; moved by [`SocSpec::at_opp`]).
    pub fn current_idx(&self) -> usize {
        self.cur
    }

    pub fn nominal(&self) -> OperatingPoint {
        self.points[self.nominal_idx()]
    }

    /// Dynamic-power scale of point `idx` relative to nominal:
    /// `(f/f_nom)·(V/V_nom)²`. Exactly 1.0 at the nominal point.
    pub fn power_scale(&self, idx: usize) -> f64 {
        let p = self.points[idx];
        let nom = self.nominal();
        (p.freq_ghz / nom.freq_ghz) * (p.volt_v / nom.volt_v) * (p.volt_v / nom.volt_v)
    }
}

/// Per-core microarchitectural description.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct CoreSpec {
    pub freq_ghz: f64,
    /// Private L1 data cache.
    pub l1d: CacheGeometry,
    /// Double-precision flops/cycle the FPU can retire from the
    /// micro-kernel's rank-1 update sequence under ideal conditions.
    /// (A15: NEON-VFPv4 FMA pipe; A7: simpler in-order VFP.)
    pub dp_flops_per_cycle: f64,
}

impl CoreSpec {
    /// Ideal peak double-precision GFLOPS of one core.
    pub fn peak_gflops(&self) -> f64 {
        self.freq_ghz * self.dp_flops_per_cycle
    }
}

/// Calibrated per-cluster model constants. These used to be global
/// `CoreType`-keyed tables in `model::calibration`; making them part of
/// the descriptor is what lets a third (or fourth…) cluster carry its
/// own amortization curve, contention profile and power rail without
/// touching the models. Every Exynos value is anchored to a number the
/// paper reports (§3.4, §4, Figs. 5/7/9/10/12) and pinned by the
/// regression tests in `tests/exynos_regression.rs`.
#[derive(Debug, Clone, PartialEq)]
pub struct ClusterTuning {
    /// Half-saturation constant of `eff_k(kc) = kc/(kc + hk)`: per-
    /// micro-kernel C load/store + loop overhead amortized over the kc
    /// rank-1 updates.
    pub hk: f64,
    /// Half-saturation constant of `eff_m(rows) = rows/(rows + hm)`:
    /// `Br` L1-warmup amortized over the rows swept per jr column.
    pub hm: f64,
    /// Per-core throughput multiplier vs. active cores in the cluster
    /// (index = active−1, clamped at the end for wider clusters).
    /// Models shared-L2/bus contention (§3.4: the 4th A15 core yields a
    /// smaller increase).
    pub cluster_scale: Vec<f64>,
    /// Effective packing bandwidth per core, GB/s (read + packed write).
    pub pack_bw_gbs: f64,
    /// Intra-cluster barrier cost, seconds.
    pub barrier_s: f64,
    /// Dynamic-chunk critical-section cost (§5.4), seconds.
    pub grab_s: f64,
    /// Power increment of one computing core above the cluster baseline,
    /// Watts.
    pub p_core_active_w: f64,
    /// Always-on cluster rail baseline, Watts.
    pub p_cluster_idle_w: f64,
    /// Fraction of the shared L2 usable by the resident `Ac` macro-panel
    /// (the rest is headroom for the `Bc` stream + C traffic).
    pub l2_fill: f64,
    /// Micro-kernel throughput factor of an 8×4 register blocking
    /// relative to the paper's 4×4 (§6 future work: >1 on out-of-order
    /// cores, <1 on in-order ones).
    pub reg_8x4_factor: f64,
}

impl ClusterTuning {
    /// Cortex-A15-class tuning (out-of-order, big rail).
    pub fn a15() -> Self {
        ClusterTuning {
            hk: 42.0,
            hm: 6.0,
            cluster_scale: vec![1.0, 1.0, 0.966, 0.814],
            pack_bw_gbs: 2.0,
            barrier_s: 3.0e-6,
            grab_s: 1.5e-6,
            p_core_active_w: 1.80,
            p_cluster_idle_w: 0.60,
            l2_fill: 0.5525,
            reg_8x4_factor: 1.05,
        }
    }

    /// Cortex-A7-class tuning (in-order, low-power rail).
    pub fn a7() -> Self {
        ClusterTuning {
            hk: 35.2,
            hm: 8.0,
            cluster_scale: vec![1.0, 1.0, 1.0, 1.0],
            pack_bw_gbs: 0.8,
            barrier_s: 8.0e-6,
            grab_s: 4.0e-6,
            p_core_active_w: 0.28,
            p_cluster_idle_w: 0.12,
            l2_fill: 0.4297,
            reg_8x4_factor: 0.97,
        }
    }

    /// Mid-class tuning for tri-cluster (DynamIQ-style) descriptors:
    /// between the A15 and A7 profiles.
    pub fn mid() -> Self {
        ClusterTuning {
            hk: 38.0,
            hm: 7.0,
            cluster_scale: vec![1.0, 1.0, 0.98, 0.90],
            pack_bw_gbs: 1.4,
            barrier_s: 5.0e-6,
            grab_s: 2.5e-6,
            p_core_active_w: 0.90,
            p_cluster_idle_w: 0.30,
            l2_fill: 0.50,
            reg_8x4_factor: 1.02,
        }
    }

    /// Contention multiplier for `active` busy cores (1-based; clamped
    /// beyond the table for ablation SoCs with wider clusters). The
    /// degenerate input `active = 0` clamps to the single-core entry
    /// instead of panicking: callers probing an idle cluster (e.g. the
    /// DVFS weight retuner over arbitrary topologies) get a neutral
    /// factor, never a NaN weight.
    pub fn scale(&self, active: usize) -> f64 {
        self.cluster_scale[active.saturating_sub(1).min(self.cluster_scale.len() - 1)]
    }

    /// Micro-kernel register-blocking factor (§6 future work: per-core
    /// micro-kernels with their own mr×nr). The paper's hand-tuned
    /// kernel is 4×4 everywhere; 8×4 halves `Br` load traffic per flop;
    /// other blockings fall back to a generic path at a small penalty.
    pub fn register_block_factor(&self, mr: usize, nr: usize) -> f64 {
        match (mr, nr) {
            (4, 4) => 1.0,
            (8, 4) => self.reg_8x4_factor,
            _ => 0.93,
        }
    }

    pub fn p_core_poll_w(&self, poll_factor: f64) -> f64 {
        self.p_core_active_w * poll_factor
    }
}

/// A cluster: n identical cores sharing one L2, plus the tuned BLIS
/// blocking parameters and the calibrated model constants for this
/// class of core.
#[derive(Debug, Clone, PartialEq)]
pub struct ClusterSpec {
    /// Microarchitecture name, e.g. "Cortex-A15".
    pub name: String,
    /// Scheduling-role shorthand, e.g. "big" / "LITTLE" / "mid" / "smp".
    pub short_name: String,
    pub core: CoreSpec,
    pub num_cores: usize,
    /// Shared, unified L2 cache of the cluster.
    pub l2: CacheGeometry,
    /// Empirically tuned blocking optimum for this cluster (§3.3 for the
    /// Exynos clusters; derived analogously for other presets).
    pub tuned: BlisParams,
    pub tuning: ClusterTuning,
    /// DVFS operating-point ladder of the cluster's rail. The nominal
    /// (last) point is the preset's boot frequency; [`SocSpec::at_opp`]
    /// derives the descriptor at any other rung, and `crate::dvfs`
    /// schedules walks over it.
    pub opps: OppTable,
}

impl ClusterSpec {
    /// Blocking parameters this cluster runs under a *shared-`Bc`*
    /// cache-aware configuration (§5.3): `kc` is pinned to the common
    /// value and `mc` refits so `Ac` still fits this cluster's L2.
    /// For the Exynos LITTLE cluster at kc = 952 this reproduces the
    /// paper's mc = 32 exactly.
    pub fn params_shared_kc(&self, kc: usize) -> BlisParams {
        self.tuned.shared_kc_refit(kc, self.l2.size_bytes)
    }

    /// Ideal aggregate peak of the cluster (sum of single-core peaks).
    pub fn peak_gflops(&self) -> f64 {
        self.core.peak_gflops() * self.num_cores as f64
    }
}

/// Whole-SoC description: the N-cluster topology plus shared memory.
#[derive(Debug, Clone, PartialEq)]
pub struct SocSpec {
    pub name: String,
    /// The clusters, fastest-first by convention in all presets.
    pub clusters: Vec<ClusterSpec>,
    /// Optional system-level cache (L3/SLC) behind every cluster's L2,
    /// shared by all clusters — the Intel P/E/LP-E and Apple P/E shape
    /// (ROADMAP ">2-level cache hierarchies"). `None` for the paper's
    /// Exynos testbed and all pre-existing presets, so the two-level
    /// analysis reproduces bit-for-bit.
    pub l3: Option<CacheGeometry>,
    /// Sustained DRAM bandwidth observable by one cluster (GB/s).
    pub dram_bw_gbs: f64,
    pub dram_total_bytes: usize,
}

impl std::ops::Index<ClusterId> for SocSpec {
    type Output = ClusterSpec;
    fn index(&self, id: ClusterId) -> &ClusterSpec {
        &self.clusters[id.0]
    }
}

impl SocSpec {
    /// The paper's testbed (§3.2, Fig. 3) — bit-for-bit the original
    /// two-cluster descriptor, so every figure reproduces unchanged.
    pub fn exynos5422() -> SocSpec {
        SocSpec {
            name: "Samsung Exynos 5422 (ODROID-XU3)".to_string(),
            clusters: vec![
                ClusterSpec {
                    name: "Cortex-A15".to_string(),
                    short_name: "big".to_string(),
                    core: CoreSpec {
                        freq_ghz: 1.6,
                        l1d: CacheGeometry::new(32 * 1024, 2, 64),
                        // Calibrated so the modelled single-core optimum
                        // lands at the paper's ~2.85 GFLOPS.
                        dp_flops_per_cycle: 2.0,
                    },
                    num_cores: 4,
                    l2: CacheGeometry::new(2 * 1024 * 1024, 16, 64),
                    tuned: BlisParams::a15_opt(),
                    tuning: ClusterTuning::a15(),
                    opps: OppTable::a15(),
                },
                ClusterSpec {
                    name: "Cortex-A7".to_string(),
                    short_name: "LITTLE".to_string(),
                    core: CoreSpec {
                        freq_ghz: 1.4,
                        l1d: CacheGeometry::new(32 * 1024, 4, 64),
                        dp_flops_per_cycle: 0.5,
                    },
                    num_cores: 4,
                    l2: CacheGeometry::new(512 * 1024, 8, 64),
                    tuned: BlisParams::a7_opt(),
                    tuning: ClusterTuning::a7(),
                    opps: OppTable::a7(),
                },
            ],
            l3: None,
            dram_bw_gbs: 3.2,
            dram_total_bytes: 2 * 1024 * 1024 * 1024,
        }
    }

    /// Generic big.LITTLE-style SoC for ablation studies (paper §6
    /// future work: "architectures with different number of big/LITTLE
    /// cores"). Scales the Exynos descriptor's core counts.
    pub fn custom_counts(num_big: usize, num_little: usize) -> SocSpec {
        assert!(num_big >= 1 && num_little >= 1);
        let mut soc = SocSpec::exynos5422();
        soc.name = format!("custom big.LITTLE {num_big}+{num_little}");
        soc.clusters[BIG.0].num_cores = num_big;
        soc.clusters[LITTLE.0].num_cores = num_little;
        soc
    }

    /// DVFS variant for two-cluster descriptors: same silicon, different
    /// operating points (§5.2: "changes in the core frequency ... affect
    /// the performance ratio between core types").
    pub fn with_freqs(self, big_ghz: f64, little_ghz: f64) -> SocSpec {
        assert_eq!(self.clusters.len(), 2, "with_freqs is the 2-cluster shorthand");
        self.with_cluster_freq(BIG, big_ghz)
            .with_cluster_freq(LITTLE, little_ghz)
    }

    /// DVFS knob for any cluster of any topology (free-form frequency;
    /// the ladder-quantized variant is [`SocSpec::at_opp`]).
    pub fn with_cluster_freq(self, id: ClusterId, ghz: f64) -> SocSpec {
        self.try_with_cluster_freq(id, ghz)
            .expect("invalid DVFS frequency")
    }

    /// Fallible [`SocSpec::with_cluster_freq`]: zero, negative or
    /// non-finite frequencies return a clean `Err` instead of panicking
    /// (they would otherwise poison every downstream rate and weight
    /// with zeros or NaNs).
    pub fn try_with_cluster_freq(mut self, id: ClusterId, ghz: f64) -> Result<SocSpec, String> {
        if id.0 >= self.clusters.len() {
            return Err(format!(
                "cluster {id} does not exist on '{}' ({} clusters)",
                self.name,
                self.clusters.len()
            ));
        }
        if !ghz.is_finite() || ghz <= 0.0 {
            return Err(format!(
                "cluster frequency must be positive and finite, got {ghz} GHz"
            ));
        }
        self.name = format!("{} [{} @ {ghz} GHz]", self.name, id);
        self.clusters[id.0].core.freq_ghz = ghz;
        Ok(self)
    }

    /// The descriptor at one cluster's ladder point `opp`: frequency set
    /// to the point's, and the cluster's power rails scaled by the CMOS
    /// dynamic-power factor `(f/f_nom)·(V/V_nom)²`. Derivation is
    /// *absolute* — the ladder remembers the rung the descriptor is
    /// currently at ([`OppTable::current_idx`]), so re-deriving an
    /// already-derived descriptor moves it to the requested rung instead
    /// of compounding the rail scaling, and deriving the current rung is
    /// exactly the identity (ratio 1.0): at the nominal rung of a
    /// freshly built preset the result is bit-for-bit the input — the
    /// no-op guarantee the DVFS regression tests pin. The name is kept:
    /// an operating point is a state of the same silicon.
    pub fn at_opp(&self, id: ClusterId, opp: usize) -> SocSpec {
        let ladder = &self.clusters[id.0].opps;
        assert!(
            opp < ladder.len(),
            "OPP index {opp} out of range: {} has {} ladder points",
            self.clusters[id.0].name,
            ladder.len()
        );
        let point = ladder.get(opp);
        let ratio = ladder.power_scale(opp) / ladder.power_scale(ladder.current_idx());
        let mut soc = self.clone();
        let cl = &mut soc.clusters[id.0];
        cl.core.freq_ghz = point.freq_ghz;
        cl.tuning.p_core_active_w *= ratio;
        cl.tuning.p_cluster_idle_w *= ratio;
        cl.opps.cur = opp;
        soc
    }

    /// ARM Juno r0 development board — the paper's §6 "port to a 64-bit
    /// ARMv8 architecture" roadmap item: 2× Cortex-A57 @ 1.1 GHz with a
    /// 2 MiB shared L2, plus 4× Cortex-A53 @ 850 MHz with a 1 MiB L2.
    /// The A57's wider NEON datapath retires more dp flops per cycle.
    pub fn juno_r0() -> SocSpec {
        SocSpec {
            name: "ARM Juno r0 (ARMv8: 2×A57 + 4×A53)".to_string(),
            clusters: vec![
                ClusterSpec {
                    name: "Cortex-A57".to_string(),
                    short_name: "big".to_string(),
                    core: CoreSpec {
                        freq_ghz: 1.1,
                        l1d: CacheGeometry::new(32 * 1024, 2, 64),
                        dp_flops_per_cycle: 4.0,
                    },
                    num_cores: 2,
                    l2: CacheGeometry::new(2 * 1024 * 1024, 16, 64),
                    tuned: BlisParams::a15_opt(),
                    tuning: ClusterTuning::a15(),
                    opps: OppTable::generic(1.1),
                },
                ClusterSpec {
                    name: "Cortex-A53".to_string(),
                    short_name: "LITTLE".to_string(),
                    core: CoreSpec {
                        freq_ghz: 0.85,
                        l1d: CacheGeometry::new(32 * 1024, 4, 64),
                        dp_flops_per_cycle: 1.0,
                    },
                    num_cores: 4,
                    l2: CacheGeometry::new(1024 * 1024, 16, 64),
                    tuned: BlisParams::a7_opt(),
                    tuning: ClusterTuning::a7(),
                    opps: OppTable::generic(0.85),
                },
            ],
            l3: None,
            dram_bw_gbs: 5.0,
            dram_total_bytes: 8 * 1024 * 1024 * 1024,
        }
    }

    /// Tri-cluster DynamIQ-style SoC (2 big + 3 mid + 4 LITTLE): the
    /// shape of modern AMPs (Arm DynamIQ, Intel P/E/LP-E, Apple P/E)
    /// that motivated generalizing beyond two clusters. Exercises the
    /// N-way weighted-static split and three distinct cache-aware
    /// control trees.
    pub fn dynamiq_3c() -> SocSpec {
        SocSpec {
            name: "DynamIQ-style tri-cluster (2 big + 3 mid + 4 LITTLE)".to_string(),
            clusters: vec![
                ClusterSpec {
                    name: "big".to_string(),
                    short_name: "big".to_string(),
                    core: CoreSpec {
                        freq_ghz: 2.2,
                        l1d: CacheGeometry::new(64 * 1024, 4, 64),
                        dp_flops_per_cycle: 4.0,
                    },
                    num_cores: 2,
                    l2: CacheGeometry::new(2 * 1024 * 1024, 16, 64),
                    tuned: BlisParams::a15_opt(),
                    tuning: ClusterTuning::a15(),
                    opps: OppTable::generic(2.2),
                },
                ClusterSpec {
                    name: "mid".to_string(),
                    short_name: "mid".to_string(),
                    core: CoreSpec {
                        freq_ghz: 1.8,
                        l1d: CacheGeometry::new(32 * 1024, 4, 64),
                        dp_flops_per_cycle: 2.0,
                    },
                    num_cores: 3,
                    // 1 MiB shared L2 → its own (mc, kc) optimum, distinct
                    // from both the big and LITTLE clusters.
                    l2: CacheGeometry::new(1024 * 1024, 16, 64),
                    tuned: BlisParams::new(4096, 704, 92, 4, 4),
                    tuning: ClusterTuning::mid(),
                    opps: OppTable::generic(1.8),
                },
                ClusterSpec {
                    name: "LITTLE".to_string(),
                    short_name: "LITTLE".to_string(),
                    core: CoreSpec {
                        freq_ghz: 1.4,
                        l1d: CacheGeometry::new(32 * 1024, 4, 64),
                        dp_flops_per_cycle: 0.5,
                    },
                    num_cores: 4,
                    l2: CacheGeometry::new(512 * 1024, 8, 64),
                    tuned: BlisParams::a7_opt(),
                    tuning: ClusterTuning::a7(),
                    opps: OppTable::generic(1.4),
                },
            ],
            l3: None,
            dram_bw_gbs: 12.0,
            dram_total_bytes: 4 * 1024 * 1024 * 1024,
        }
    }

    /// Symmetric SMP degenerate case: one cluster of identical cores.
    /// On this topology SSS, SAS(uniform weights) and DAS must all
    /// collapse to the same plain BLIS-style parallel GEMM — the sanity
    /// anchor of the N-cluster generalization.
    pub fn symmetric(num_cores: usize) -> SocSpec {
        assert!(num_cores >= 1);
        SocSpec {
            name: format!("symmetric SMP ({num_cores}×A15-class)"),
            clusters: vec![ClusterSpec {
                name: "Cortex-A15".to_string(),
                short_name: "smp".to_string(),
                core: CoreSpec {
                    freq_ghz: 1.6,
                    l1d: CacheGeometry::new(32 * 1024, 2, 64),
                    dp_flops_per_cycle: 2.0,
                },
                num_cores,
                l2: CacheGeometry::new(2 * 1024 * 1024, 16, 64),
                tuned: BlisParams::a15_opt(),
                tuning: ClusterTuning::a15(),
                opps: OppTable::generic(1.6),
            }],
            l3: None,
            dram_bw_gbs: 3.2,
            dram_total_bytes: 2 * 1024 * 1024 * 1024,
        }
    }

    /// Synthetic Intel-style P/E hybrid: 4 performance cores against
    /// 4 efficiency cores, both clusters backed by a shared 12 MiB
    /// system-level cache. The only preset with `l3: Some(..)` — it
    /// exercises the three-level footprint analysis (an `Ac` macro-panel
    /// that spills a small E-cluster L2 lands in the SLC instead of
    /// DRAM) without perturbing the paper's two-level Exynos presets.
    pub fn pe_hybrid() -> SocSpec {
        SocSpec {
            name: "synthetic P/E hybrid (4P + 4E, 12 MiB SLC)".to_string(),
            clusters: vec![
                ClusterSpec {
                    name: "P-core".to_string(),
                    short_name: "big".to_string(),
                    core: CoreSpec {
                        freq_ghz: 2.4,
                        l1d: CacheGeometry::new(48 * 1024, 12, 64),
                        dp_flops_per_cycle: 4.0,
                    },
                    num_cores: 4,
                    l2: CacheGeometry::new(2 * 1024 * 1024, 16, 64),
                    tuned: BlisParams::a15_opt(),
                    tuning: ClusterTuning::a15(),
                    opps: OppTable::generic(2.4),
                },
                ClusterSpec {
                    name: "E-core".to_string(),
                    short_name: "LITTLE".to_string(),
                    core: CoreSpec {
                        freq_ghz: 1.8,
                        l1d: CacheGeometry::new(32 * 1024, 8, 64),
                        dp_flops_per_cycle: 2.0,
                    },
                    num_cores: 4,
                    // Small module-shared L2: the A15-class Ac (1.16 MiB)
                    // overflows it but fits the SLC.
                    l2: CacheGeometry::new(512 * 1024, 8, 64),
                    tuned: BlisParams::a7_opt(),
                    tuning: ClusterTuning::mid(),
                    opps: OppTable::generic(1.8),
                },
            ],
            l3: Some(CacheGeometry::new(12 * 1024 * 1024, 12, 64)),
            dram_bw_gbs: 20.0,
            dram_total_bytes: 16 * 1024 * 1024 * 1024,
        }
    }

    /// Attach (or replace) a system-level cache on any descriptor —
    /// the ablation knob for >2-level hierarchies.
    pub fn with_l3(mut self, geo: CacheGeometry) -> SocSpec {
        geo.validate();
        self.l3 = Some(geo);
        self
    }

    pub fn num_clusters(&self) -> usize {
        self.clusters.len()
    }

    /// Iterate every cluster id, in order.
    pub fn cluster_ids(&self) -> impl Iterator<Item = ClusterId> {
        (0..self.clusters.len()).map(ClusterId)
    }

    pub fn cluster(&self, id: ClusterId) -> &ClusterSpec {
        &self.clusters[id.0]
    }

    /// The cluster with the highest per-core peak (ties → lowest index).
    /// Architecture-oblivious configurations run its tuned parameters
    /// everywhere (§4: "cache configuration parameters are set to those
    /// that are optimal for the Cortex-A15").
    pub fn lead(&self) -> ClusterId {
        let mut best = ClusterId(0);
        for id in self.cluster_ids() {
            if self[id].core.peak_gflops() > self[best].core.peak_gflops() {
                best = id;
            }
        }
        best
    }

    pub fn total_cores(&self) -> usize {
        self.clusters.iter().map(|c| c.num_cores).sum()
    }

    /// Global core id range of a cluster: cluster 0's cores come first,
    /// then cluster 1's, and so on. The simulator, native executor and
    /// energy meter all share this numbering.
    pub fn core_ids(&self, id: ClusterId) -> std::ops::Range<usize> {
        let start: usize = self.clusters[..id.0].iter().map(|c| c.num_cores).sum();
        start..start + self.clusters[id.0].num_cores
    }

    pub fn cluster_of_core(&self, core_id: usize) -> ClusterId {
        let mut start = 0;
        for id in self.cluster_ids() {
            start += self[id].num_cores;
            if core_id < start {
                return id;
            }
        }
        panic!("core id {core_id} out of range");
    }

    /// Ideal aggregate peak (sum of single-core peaks) — upper bound
    /// reference only; the perf model applies efficiency + contention.
    pub fn aggregate_peak_gflops(&self) -> f64 {
        self.clusters.iter().map(ClusterSpec::peak_gflops).sum()
    }

    /// Re-check every cluster's OPP ladder invariants on a whole
    /// descriptor: non-empty, strictly ascending frequency with
    /// non-decreasing voltage, positive finite points, derivation rung
    /// in range. [`OppTable::new`] enforces all of this for tables it
    /// builds, but governors index `opps.len() - 1` and trust
    /// `current_idx` unconditionally — so descriptors are re-validated
    /// in one place where they *enter* the system (board construction,
    /// governor planning) rather than deep inside a plan (ISSUE 8).
    pub fn validate_ladders(&self) -> Result<(), String> {
        for c in &self.clusters {
            let ladder = &c.opps;
            if ladder.is_empty() {
                return Err(format!("{}: cluster '{}' has an empty OPP ladder", self.name, c.name));
            }
            if ladder.current_idx() >= ladder.len() {
                return Err(format!(
                    "{}: cluster '{}' derived at rung {} of a {}-point ladder",
                    self.name,
                    c.name,
                    ladder.current_idx(),
                    ladder.len()
                ));
            }
            for (i, p) in ladder.points().iter().enumerate() {
                if !(p.freq_ghz.is_finite() && p.freq_ghz > 0.0 && p.volt_v.is_finite() && p.volt_v > 0.0)
                {
                    return Err(format!(
                        "{}: cluster '{}' OPP {i} is not positive finite ({} GHz, {} V)",
                        self.name, c.name, p.freq_ghz, p.volt_v
                    ));
                }
            }
            for (i, w) in ladder.points().windows(2).enumerate() {
                if !(w[0].freq_ghz < w[1].freq_ghz && w[0].volt_v <= w[1].volt_v) {
                    return Err(format!(
                        "{}: cluster '{}' OPP ladder must ascend at rung {}..{}",
                        self.name,
                        c.name,
                        i,
                        i + 1
                    ));
                }
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn exynos_matches_paper_spec() {
        let soc = SocSpec::exynos5422();
        assert_eq!(soc.num_clusters(), 2);
        assert_eq!(soc[BIG].num_cores, 4);
        assert_eq!(soc[LITTLE].num_cores, 4);
        assert_eq!(soc[BIG].core.freq_ghz, 1.6);
        assert_eq!(soc[LITTLE].core.freq_ghz, 1.4);
        assert_eq!(soc[BIG].l2.size_bytes, 2 * 1024 * 1024);
        assert_eq!(soc[LITTLE].l2.size_bytes, 512 * 1024);
        assert_eq!(soc[BIG].core.l1d.size_bytes, 32 * 1024);
        assert_eq!(soc[LITTLE].core.l1d.size_bytes, 32 * 1024);
        assert_eq!(soc[BIG].name, "Cortex-A15");
        assert_eq!(soc[LITTLE].short_name, "LITTLE");
    }

    #[test]
    fn l2_ratio_is_four() {
        let soc = SocSpec::exynos5422();
        assert_eq!(soc[BIG].l2.size_bytes / soc[LITTLE].l2.size_bytes, 4);
    }

    #[test]
    fn core_id_mapping_round_trips() {
        for soc in [SocSpec::exynos5422(), SocSpec::dynamiq_3c(), SocSpec::symmetric(6)] {
            let mut seen = 0;
            for id in soc.cluster_ids() {
                for gid in soc.core_ids(id) {
                    assert_eq!(soc.cluster_of_core(gid), id);
                    assert_eq!(gid, seen);
                    seen += 1;
                }
            }
            assert_eq!(seen, soc.total_cores());
        }
        assert_eq!(SocSpec::exynos5422().total_cores(), 8);
    }

    #[test]
    #[should_panic(expected = "out of range")]
    fn cluster_of_core_out_of_range_panics() {
        SocSpec::exynos5422().cluster_of_core(8);
    }

    #[test]
    fn cache_geometry_sets() {
        let g = CacheGeometry::new(32 * 1024, 2, 64);
        assert_eq!(g.num_sets(), 256);
    }

    #[test]
    #[should_panic]
    fn bad_cache_geometry_rejected() {
        CacheGeometry::new(33 * 1024, 2, 64);
    }

    #[test]
    fn big_cores_faster_than_little() {
        let soc = SocSpec::exynos5422();
        assert!(soc[BIG].core.peak_gflops() > 3.0 * soc[LITTLE].core.peak_gflops());
        assert_eq!(soc.lead(), BIG);
    }

    #[test]
    fn custom_counts_builder() {
        let soc = SocSpec::custom_counts(2, 6);
        assert_eq!(soc.total_cores(), 8);
        assert_eq!(soc.core_ids(LITTLE), 2..8);
    }

    #[test]
    fn aggregate_peak_positive() {
        assert!(SocSpec::exynos5422().aggregate_peak_gflops() > 10.0);
    }

    #[test]
    fn tri_cluster_topology_is_well_formed() {
        let soc = SocSpec::dynamiq_3c();
        assert_eq!(soc.num_clusters(), 3);
        assert_eq!(soc.total_cores(), 9);
        assert_eq!(soc.lead(), ClusterId(0));
        // Strictly descending per-core peaks, distinct L2 geometries.
        for w in soc.clusters.windows(2) {
            assert!(w[0].core.peak_gflops() > w[1].core.peak_gflops());
        }
        for c in &soc.clusters {
            c.tuned.validate();
            c.l2.validate();
        }
    }

    /// ISSUE 8 satellite: whole-descriptor ladder validation — every
    /// preset passes, single-point ladders are legal (no DVFS), and
    /// forged degenerate ladders are reported instead of underflowing
    /// in a governor's `len() - 1` arithmetic later.
    #[test]
    fn validate_ladders_accepts_presets_and_rejects_forgeries() {
        for soc in [
            SocSpec::exynos5422(),
            SocSpec::juno_r0(),
            SocSpec::dynamiq_3c(),
            SocSpec::pe_hybrid(),
            SocSpec::symmetric(4),
        ] {
            soc.validate_ladders().unwrap_or_else(|e| panic!("{}: {e}", soc.name));
        }
        let mut single = SocSpec::symmetric(2);
        for c in &mut single.clusters {
            c.opps = OppTable::single(c.core.freq_ghz);
        }
        single.validate_ladders().unwrap();
        // Forgeries (same-module field access; external code cannot
        // build these through `OppTable`'s constructors).
        let mut empty = SocSpec::exynos5422();
        empty.clusters[0].opps.points.clear();
        let err = empty.validate_ladders().unwrap_err();
        assert!(err.contains("empty OPP ladder"), "{err}");
        let mut descending = SocSpec::exynos5422();
        descending.clusters[1].opps.points.reverse();
        let err = descending.validate_ladders().unwrap_err();
        assert!(err.contains("must ascend"), "{err}");
        let mut out_of_range = SocSpec::exynos5422();
        out_of_range.clusters[0].opps.cur = 99;
        let err = out_of_range.validate_ladders().unwrap_err();
        assert!(err.contains("derived at rung"), "{err}");
    }

    #[test]
    fn symmetric_preset_degenerates_to_one_cluster() {
        let soc = SocSpec::symmetric(4);
        assert_eq!(soc.num_clusters(), 1);
        assert_eq!(soc.core_ids(ClusterId(0)), 0..4);
        assert_eq!(soc.lead(), ClusterId(0));
    }

    #[test]
    fn shared_kc_refit_reproduces_paper_mc32() {
        // §5.3: the Exynos LITTLE cluster at the shared kc = 952 must
        // land on the paper's (mc, kc) = (32, 952) bit-for-bit.
        let soc = SocSpec::exynos5422();
        assert_eq!(soc[LITTLE].params_shared_kc(952), BlisParams::a7_shared_kc());
        // The big cluster's own kc needs no refit.
        assert_eq!(soc[BIG].params_shared_kc(952), BlisParams::a15_opt());
    }

    #[test]
    fn dvfs_builders() {
        let soc = SocSpec::exynos5422().with_freqs(0.8, 1.4);
        assert_eq!(soc[BIG].core.freq_ghz, 0.8);
        assert_eq!(soc[LITTLE].core.freq_ghz, 1.4);
        let tri = SocSpec::dynamiq_3c().with_cluster_freq(ClusterId(1), 1.2);
        assert_eq!(tri.clusters[1].core.freq_ghz, 1.2);
    }

    #[test]
    fn tuning_helpers() {
        let t = ClusterTuning::a15();
        assert_eq!(t.scale(8), t.cluster_scale[3], "clamps beyond table");
        assert_eq!(t.register_block_factor(4, 4), 1.0);
        assert_eq!(t.register_block_factor(8, 4), 1.05);
        assert_eq!(t.register_block_factor(2, 8), 0.93);
        assert!(ClusterTuning::a7().register_block_factor(8, 4) < 1.0);
    }

    #[test]
    fn zero_active_cores_clamps_instead_of_panicking() {
        // ISSUE 3 satellite: the degenerate input must not panic or
        // produce a NaN-poisoning factor.
        for t in [ClusterTuning::a15(), ClusterTuning::mid(), ClusterTuning::a7()] {
            let s = t.scale(0);
            assert_eq!(s, t.scale(1), "0 active clamps to the single-core entry");
            assert!(s.is_finite() && s > 0.0);
        }
    }

    #[test]
    fn zero_frequency_rejected_cleanly() {
        for bad in [0.0, -1.4, f64::NAN, f64::INFINITY] {
            let err = SocSpec::exynos5422()
                .try_with_cluster_freq(BIG, bad)
                .unwrap_err();
            assert!(err.contains("positive and finite"), "{err}");
        }
        let err = SocSpec::exynos5422()
            .try_with_cluster_freq(ClusterId(9), 1.0)
            .unwrap_err();
        assert!(err.contains("does not exist"), "{err}");
    }

    #[test]
    fn exynos_opp_ladders_match_the_paper_operating_point() {
        let soc = SocSpec::exynos5422();
        assert_eq!(soc[BIG].opps.len(), 5);
        assert_eq!(soc[LITTLE].opps.len(), 5);
        // The nominal (boot) rung is exactly the §3.2 frequency.
        assert_eq!(soc[BIG].opps.nominal().freq_ghz, 1.6);
        assert_eq!(soc[LITTLE].opps.nominal().freq_ghz, 1.4);
        assert_eq!(soc[BIG].opps.nominal_idx(), 4);
        // Every preset's ladder tops out at its boot frequency.
        for preset in [
            SocSpec::exynos5422(),
            SocSpec::juno_r0(),
            SocSpec::dynamiq_3c(),
            SocSpec::symmetric(4),
            SocSpec::pe_hybrid(),
        ] {
            for id in preset.cluster_ids() {
                let cl = &preset[id];
                assert_eq!(
                    cl.opps.nominal().freq_ghz,
                    cl.core.freq_ghz,
                    "{}/{} ladder nominal != boot frequency",
                    preset.name,
                    cl.name
                );
            }
        }
    }

    #[test]
    fn at_opp_nominal_is_bit_for_bit_identity() {
        let soc = SocSpec::exynos5422();
        let same = soc.at_opp(BIG, 4).at_opp(LITTLE, 4);
        assert_eq!(same, soc);
    }

    #[test]
    fn at_opp_scales_frequency_and_rails() {
        let soc = SocSpec::exynos5422();
        let down = soc.at_opp(BIG, 0);
        assert_eq!(down[BIG].core.freq_ghz, 0.8);
        // f·V² law: 0.5 × (0.9/1.1625)² ≈ 0.2997.
        let s = soc[BIG].opps.power_scale(0);
        assert!((0.25..0.35).contains(&s), "power scale {s}");
        assert!((down[BIG].tuning.p_core_active_w - 1.80 * s).abs() < 1e-12);
        assert!((down[BIG].tuning.p_cluster_idle_w - 0.60 * s).abs() < 1e-12);
        // The LITTLE cluster is untouched.
        assert_eq!(down[LITTLE], soc[LITTLE]);
        // Ladder rungs are strictly slower below nominal.
        for o in 0..soc[BIG].opps.len() - 1 {
            assert!(soc[BIG].opps.get(o).freq_ghz < soc[BIG].opps.get(o + 1).freq_ghz);
            assert!(soc[BIG].opps.power_scale(o) < soc[BIG].opps.power_scale(o + 1));
        }
    }

    #[test]
    #[should_panic(expected = "out of range")]
    fn at_opp_rejects_bad_index() {
        SocSpec::exynos5422().at_opp(BIG, 9);
    }

    #[test]
    fn at_opp_is_absolute_not_compounding() {
        // Re-deriving an already-derived descriptor moves it, never
        // stacks the rail scaling (the `@governor` board + schedule
        // replay path exercises exactly this chain).
        let soc = SocSpec::exynos5422();
        let down = soc.at_opp(BIG, 0);
        assert_eq!(down[BIG].opps.current_idx(), 0);
        // Idempotent, exactly.
        assert_eq!(down.at_opp(BIG, 0), down);
        // Deriving back up restores the nominal frequency and rails
        // (rails up to fp rounding of the ratio round-trip).
        let back = down.at_opp(BIG, 4);
        assert_eq!(back[BIG].core.freq_ghz, 1.6);
        assert_eq!(back[BIG].opps.current_idx(), 4);
        assert!((back[BIG].tuning.p_core_active_w - 1.80).abs() < 1e-12);
        assert!((back[BIG].tuning.p_cluster_idle_w - 0.60).abs() < 1e-12);
    }

    #[test]
    #[should_panic(expected = "ascend")]
    fn descending_opp_ladder_rejected() {
        OppTable::new(vec![
            OperatingPoint::new(1.6, 1.1),
            OperatingPoint::new(0.8, 0.9),
        ]);
    }

    #[test]
    fn single_point_ladder_degenerates() {
        let t = OppTable::single(1.6);
        assert_eq!(t.len(), 1);
        assert_eq!(t.nominal_idx(), 0);
        assert_eq!(t.power_scale(0), 1.0);
    }

    #[test]
    fn existing_presets_have_no_l3() {
        // Bit-for-bit guard: the two-level presets must not grow an SLC.
        for soc in [
            SocSpec::exynos5422(),
            SocSpec::juno_r0(),
            SocSpec::dynamiq_3c(),
            SocSpec::symmetric(4),
            SocSpec::custom_counts(2, 6),
        ] {
            assert!(soc.l3.is_none(), "{} must stay two-level", soc.name);
        }
    }

    #[test]
    fn pe_hybrid_preset_has_slc() {
        let soc = SocSpec::pe_hybrid();
        assert_eq!(soc.num_clusters(), 2);
        let l3 = soc.l3.expect("P/E preset carries an SLC");
        assert_eq!(l3.size_bytes, 12 * 1024 * 1024);
        l3.validate();
        assert!(soc[BIG].core.peak_gflops() > soc[LITTLE].core.peak_gflops());
        // The P-class Ac overflows the E cluster's small L2 but is far
        // smaller than the SLC — the three-level analysis test case.
        let ac = soc[BIG].tuned.mc * soc[BIG].tuned.kc * 8;
        assert!(ac > soc[LITTLE].l2.size_bytes);
        assert!(ac < l3.size_bytes);
    }

    #[test]
    fn with_l3_builder_attaches_slc() {
        let soc = SocSpec::exynos5422().with_l3(CacheGeometry::new(4 * 1024 * 1024, 16, 64));
        assert_eq!(soc.l3.unwrap().size_bytes, 4 * 1024 * 1024);
    }

    #[test]
    fn cluster_id_labels() {
        assert_eq!(BIG.label(), "c0");
        assert_eq!(format!("{LITTLE}"), "c1");
    }
}
