//! Hot-path benchmarks for the native executor (DESIGN.md §10):
//! micro-kernel throughput, packing bandwidth, sequential blocked GEMM
//! and the full parallel executor across schedules.

use amp_gemm::blis::gemm::{gemm_blocked, GemmShape, Workspace};
use amp_gemm::blis::microkernel::{micro_kernel_4x4, micro_kernel_8x4, micro_kernel_generic};
use amp_gemm::blis::packing::{pack_a, pack_b};
use amp_gemm::blis::params::BlisParams;
use amp_gemm::native::gemm_parallel;
use amp_gemm::sched::ScheduleSpec;
use amp_gemm::soc::{SocSpec, BIG};
use amp_gemm::util::benchkit::Bencher;
use amp_gemm::util::rng::Rng;

fn main() {
    let mut b = Bencher::default();
    let mut rng = Rng::new(0xBE7C);

    // ---- micro-kernel: the innermost hot path ----------------------
    for kc in [352usize, 952] {
        let a = rng.fill_matrix(4 * kc);
        let bb = rng.fill_matrix(4 * kc);
        let mut c = vec![0.0; 16];
        let flops = 2.0 * 4.0 * 4.0 * kc as f64;
        b.bench_throughput(&format!("micro_kernel_4x4 kc={kc}"), flops, "flop", || {
            micro_kernel_4x4(kc, &a, &bb, &mut c, 4);
            c[0]
        });
        b.bench_throughput(&format!("micro_kernel_generic 4x4 kc={kc}"), flops, "flop", || {
            micro_kernel_generic(4, 4, kc, &a, &bb, &mut c, 4, 4, 4);
            c[0]
        });
    }

    // 8x4 per-core-type register block (§6 future work).
    {
        let kc = 952;
        let a = rng.fill_matrix(8 * kc);
        let bb = rng.fill_matrix(4 * kc);
        let mut c = vec![0.0; 32];
        let flops = 2.0 * 8.0 * 4.0 * kc as f64;
        b.bench_throughput("micro_kernel_8x4 kc=952", flops, "flop", || {
            micro_kernel_8x4(kc, &a, &bb, &mut c, 4);
            c[0]
        });
    }

    // ---- packing routines ------------------------------------------
    let p = BlisParams::a15_opt();
    let big_src = rng.fill_matrix(512 * 1024);
    let mut buf = Vec::new();
    let pa_bytes = (p.mc * p.kc * 8) as f64;
    b.bench_throughput("pack_a 152x952", pa_bytes, "byte", || {
        pack_a(&big_src, 1024, 0, 0, p.mc, p.kc.min(1024), p.mr, &mut buf);
        buf.len()
    });
    let pb_bytes = (p.kc.min(512) * 1024 * 8) as f64;
    b.bench_throughput("pack_b 512x1024", pb_bytes, "byte", || {
        pack_b(&big_src, 1024, 0, 0, p.kc.min(512), 1024, p.nr, &mut buf);
        buf.len()
    });

    // ---- sequential blocked GEMM ------------------------------------
    for r in [256usize, 512] {
        let a = rng.fill_matrix(r * r);
        let bb = rng.fill_matrix(r * r);
        let mut c = vec![0.0; r * r];
        let mut ws = Workspace::default();
        let flops = 2.0 * (r as f64).powi(3);
        b.bench_throughput(&format!("gemm_blocked seq r={r}"), flops, "flop", || {
            c.iter_mut().for_each(|x| *x = 0.0);
            gemm_blocked(&p, GemmShape::square(r), &a, &bb, &mut c, &mut ws);
            c[0]
        });
    }

    // ---- parallel executor across schedules -------------------------
    let soc = SocSpec::exynos5422();
    let r = 512;
    let a = rng.fill_matrix(r * r);
    let bb = rng.fill_matrix(r * r);
    let flops = 2.0 * (r as f64).powi(3);
    for spec in [
        ScheduleSpec::cluster_only(BIG, 4),
        ScheduleSpec::sss(),
        ScheduleSpec::sas(5.0),
        ScheduleSpec::ca_das(),
    ] {
        let mut c = vec![0.0; r * r];
        b.bench_throughput(&format!("gemm_parallel {} r={r}", spec.label()), flops, "flop", || {
            c.iter_mut().for_each(|x| *x = 0.0);
            gemm_parallel(&soc, &spec, GemmShape::square(r), &a, &bb, &mut c);
            c[0]
        });
    }

    b.report("native hot path");
}
