//! Regenerates paper Fig. 12 (see DESIGN.md §9 experiment index).
fn main() {
    amp_gemm::figures::bench_figure_main(12);
}
