//! PJRT runtime benchmarks: artifact execution latency/throughput per
//! shape and variant, plus dispatch overhead through the runtime-thread
//! handle (DESIGN.md §10). Requires `make artifacts`.

use amp_gemm::blis::gemm::GemmShape;
use amp_gemm::runtime::worker::PjrtHandle;
use amp_gemm::util::benchkit::Bencher;
use amp_gemm::util::rng::Rng;
use std::path::Path;

fn main() {
    let dir = Path::new("artifacts");
    if !dir.join("manifest.txt").exists() {
        eprintln!("SKIP runtime_pjrt: run `make artifacts` first");
        return;
    }
    let h = PjrtHandle::spawn(dir).expect("runtime");
    let mut b = Bencher::default();
    let mut rng = Rng::new(0x915);

    for (r, variant) in [(64usize, "big"), (128, "big"), (256, "big"), (512, "big"), (256, "little")] {
        let a = rng.fill_matrix(r * r);
        let bb = rng.fill_matrix(r * r);
        let flops = 2.0 * (r as f64).powi(3);
        let shape = GemmShape::square(r);
        b.bench_throughput(
            &format!("pjrt exec gemm_{variant}_{r}"),
            flops,
            "flop",
            || {
                h.execute(shape, variant, a.clone(), bb.clone())
                    .expect("execute")
                    .1[0]
            },
        );
    }

    // Pure dispatch overhead: the names() round-trip has no compute.
    b.bench("handle round-trip (names)", || h.names().unwrap().len());

    b.report("PJRT runtime");
    h.shutdown();
}
