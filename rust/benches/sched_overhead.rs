//! Scheduling-machinery micro-benchmarks: partitioners, the dynamic
//! chunk queue (the §5.4 critical section), control-tree construction
//! and the coordinator's batch grouping. None of these may show up in
//! a GEMM profile — this bench keeps them honest (DESIGN.md §10).

use amp_gemm::blis::gemm::GemmShape;
use amp_gemm::coordinator::{Backend, Coordinator, Request};
use amp_gemm::partition::{split_ratio, split_symmetric, DynamicQueue};
use amp_gemm::sched::ScheduleSpec;
use amp_gemm::soc::SocSpec;
use amp_gemm::util::benchkit::Bencher;
use amp_gemm::util::rng::Rng;
use std::sync::Arc;

fn main() {
    let mut b = Bencher::default();

    b.bench("split_symmetric 4096/8", || split_symmetric(4096, 8, 4).len());
    b.bench("split_ratio 6144 r=5", || split_ratio(6144, 5.0, 4).0.len);

    b.bench("dynamic queue drain 6144/152", || {
        let q = DynamicQueue::new(6144);
        let mut n = 0;
        while q.grab(152).is_some() {
            n += 1;
        }
        n
    });

    b.bench("dynamic queue contended drain (8 threads)", || {
        let q = Arc::new(DynamicQueue::new(20_000));
        std::thread::scope(|s| {
            for t in 0..8 {
                let q = q.clone();
                s.spawn(move || {
                    let size = if t < 4 { 152 } else { 32 };
                    while q.grab(size).is_some() {}
                });
            }
        });
        q.remaining()
    });

    let soc = SocSpec::exynos5422();
    b.bench("cache-aware TreeSet construction (CA-DAS)", || {
        ScheduleSpec::ca_das().tree_set(&soc).is_cache_aware()
    });
    let tri = SocSpec::dynamiq_3c();
    b.bench("cache-aware TreeSet construction (tri-cluster)", || {
        ScheduleSpec::ca_das().tree_set(&tri).num_clusters()
    });

    // Coordinator batch grouping + dispatch overhead (sim backend: the
    // virtual run is microseconds, so this measures the plumbing).
    let coord = Coordinator::new(SocSpec::exynos5422());
    let mut rng = Rng::new(1);
    let reqs: Vec<Request> = (0..16)
        .map(|i| {
            let r = [256usize, 512][i % 2];
            Request {
                id: i as u64,
                shape: GemmShape::square(r),
                a: Arc::new(rng.fill_matrix(1)),
                b: Arc::new(rng.fill_matrix(1)),
                backend: Backend::Sim(ScheduleSpec::ca_das()),
            }
        })
        .collect();
    b.bench("coordinator batch of 16 sim jobs", || {
        coord.execute_batch(reqs.clone()).len()
    });

    b.report("scheduling machinery");
}
