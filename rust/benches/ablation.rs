//! Future-work ablation suite (§6 roadmap): core counts, DVFS, ARMv8
//! port, per-core micro-kernels. See figures::ablation.
fn main() {
    let fig = amp_gemm::figures::ablation::run(false);
    println!("{}", fig.to_markdown());
    if !fig.passed() {
        std::process::exit(1);
    }
}
