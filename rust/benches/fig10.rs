//! Regenerates paper Fig. 10 (see DESIGN.md §7 experiment index).
fn main() {
    amp_gemm::figures::bench_figure_main(10);
}
