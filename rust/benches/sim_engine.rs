//! DES engine throughput: simulated runs per second across strategies
//! and problem sizes. The engine must stay fast enough that the full
//! figure suite regenerates in seconds (DESIGN.md §9).

use amp_gemm::blis::gemm::GemmShape;
use amp_gemm::model::PerfModel;
use amp_gemm::sched::ScheduleSpec;
use amp_gemm::sim::simulate;
use amp_gemm::util::benchkit::Bencher;

fn main() {
    let model = PerfModel::exynos();
    let mut b = Bencher::default();

    for r in [512usize, 2048, 6144] {
        for spec in [
            ScheduleSpec::sas(5.0),
            ScheduleSpec::ca_das(),
        ] {
            b.bench(&format!("simulate {} r={r}", spec.label()), || {
                simulate(&model, &spec, GemmShape::square(r)).time_s
            });
        }
    }

    // The figure-suite workload: every strategy at the quick sizes.
    b.bench("full quick figure suite", || {
        amp_gemm::figures::run_all(&model, true).len()
    });

    b.report("sim engine");
}
