//! DES engine throughput: simulated runs per second across strategies
//! and problem sizes. The engine must stay fast enough that the full
//! figure suite regenerates in seconds (DESIGN.md §10).

use amp_gemm::blis::gemm::GemmShape;
use amp_gemm::figures::fleet::pinned_stream_fleet;
use amp_gemm::fleet::sim::{poisson_arrivals, simulate_fleet_stream_cached};
use amp_gemm::model::PerfModel;
use amp_gemm::sched::ScheduleSpec;
use amp_gemm::sim::{simulate, RunCache};
use amp_gemm::util::benchkit::Bencher;
use amp_gemm::util::rng::Rng;

fn main() {
    let model = PerfModel::exynos();
    let mut b = Bencher::default();

    for r in [512usize, 2048, 6144] {
        for spec in [
            ScheduleSpec::sas(5.0),
            ScheduleSpec::ca_das(),
        ] {
            b.bench(&format!("simulate {} r={r}", spec.label()), || {
                simulate(&model, &spec, GemmShape::square(r)).time_s
            });
        }
    }

    // The figure-suite workload: every strategy at the quick sizes.
    b.bench("full quick figure suite", || {
        amp_gemm::figures::run_all(&model, true).len()
    });

    // Streaming engine: a 100k-request Poisson sweep near the pinned
    // pair's capacity, replayed over a warm run cache so the bench
    // times the event loop (heap pops, grabs, depth bookkeeping), not
    // the six intra-SoC DES runs the cache collapses the stream onto.
    let fleet = pinned_stream_fleet();
    let shapes = [256, 384, 512].map(GemmShape::square);
    let arrivals = poisson_arrivals(&mut Rng::new(0xE7E_17), &shapes, 100_000, 120.0);
    let mut cache = RunCache::new();
    let warm = simulate_fleet_stream_cached(&fleet, &arrivals, &mut cache);
    let grabs: u64 = warm.boards.iter().map(|bd| bd.grabs).sum();
    let events = (warm.requests as u64 + grabs) as f64;
    println!(
        "stream sweep: {} requests, {grabs} grabs, {} DES runs, cache hit rate {:.4}",
        warm.requests,
        warm.des_runs,
        cache.hit_rate()
    );
    b.bench_throughput("stream sweep 100k warm cache", events, "events", || {
        simulate_fleet_stream_cached(&fleet, &arrivals, &mut cache).makespan_s
    });

    b.report("sim engine");
}
