//! Offline stub of the `xla` crate: the build environment has no crate
//! registry (and no XLA/PJRT native libraries), so the workspace
//! carries this API-compatible stand-in for the handful of types
//! `amp_gemm::runtime` uses. Everything compiles; anything that would
//! actually need the PJRT runtime returns [`XlaError`] at runtime.
//!
//! The artifact-driven paths degrade exactly like a missing
//! `artifacts/` directory: `PjRtClient::cpu()` fails, so
//! `Runtime::new` / `PjrtHandle::spawn` surface an error and the
//! coordinator falls back to the native/sim backends (all PJRT tests
//! and benches already skip when `artifacts/manifest.txt` is absent).
//! Swapping this stub for the real crate is a dependency-line change;
//! no source edits.

/// Error raised by every stubbed PJRT operation.
#[derive(Debug, Clone)]
pub struct XlaError(String);

impl std::fmt::Display for XlaError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(&self.0)
    }
}

impl std::error::Error for XlaError {}

fn unavailable(what: &str) -> XlaError {
    XlaError(format!(
        "{what}: built against the offline xla stub (no PJRT runtime in this environment)"
    ))
}

/// Result alias matching the real crate's fallible API.
pub type Result<T> = std::result::Result<T, XlaError>;

/// Stub of the parsed HLO module proto.
#[derive(Debug, Clone, Default)]
pub struct HloModuleProto;

impl HloModuleProto {
    pub fn from_text_file(_path: &str) -> Result<HloModuleProto> {
        Err(unavailable("HloModuleProto::from_text_file"))
    }
}

/// Stub of an XLA computation built from a module proto.
#[derive(Debug, Clone, Default)]
pub struct XlaComputation;

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation
    }
}

/// Stub of a host literal (dense array value).
#[derive(Debug, Clone, Default)]
pub struct Literal;

impl Literal {
    pub fn vec1<T>(_v: &[T]) -> Literal {
        Literal
    }

    pub fn reshape(&self, _dims: &[i64]) -> Result<Literal> {
        Err(unavailable("Literal::reshape"))
    }

    pub fn to_tuple1(&self) -> Result<Literal> {
        Err(unavailable("Literal::to_tuple1"))
    }

    pub fn to_vec<T>(&self) -> Result<Vec<T>> {
        Err(unavailable("Literal::to_vec"))
    }
}

/// Stub of a device-resident buffer.
#[derive(Debug, Clone, Default)]
pub struct PjRtBuffer;

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        Err(unavailable("PjRtBuffer::to_literal_sync"))
    }
}

/// Stub of a compiled, loaded executable.
#[derive(Debug, Clone, Default)]
pub struct PjRtLoadedExecutable;

impl PjRtLoadedExecutable {
    pub fn execute<L>(&self, _args: &[L]) -> Result<Vec<Vec<PjRtBuffer>>> {
        Err(unavailable("PjRtLoadedExecutable::execute"))
    }
}

/// Stub of the PJRT client handle.
#[derive(Debug, Clone, Default)]
pub struct PjRtClient;

impl PjRtClient {
    /// The real entry point; in the stub it fails immediately so
    /// callers surface a clean "runtime unavailable" error instead of
    /// a deep one.
    pub fn cpu() -> Result<PjRtClient> {
        Err(unavailable("PjRtClient::cpu"))
    }

    pub fn compile(&self, _comp: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        Err(unavailable("PjRtClient::compile"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn every_runtime_path_reports_unavailable() {
        assert!(PjRtClient::cpu().is_err());
        assert!(HloModuleProto::from_text_file("x.hlo.txt").is_err());
        let lit = Literal::vec1(&[1.0f64, 2.0]);
        assert!(lit.reshape(&[1, 2]).is_err());
        assert!(lit.to_tuple1().is_err());
        assert!(lit.to_vec::<f64>().is_err());
        assert!(PjRtBuffer.to_literal_sync().is_err());
        let exe = PjRtLoadedExecutable;
        let err = exe.execute::<Literal>(&[]).unwrap_err();
        assert!(err.to_string().contains("offline xla stub"), "{err}");
        let comp = XlaComputation::from_proto(&HloModuleProto);
        assert!(PjRtClient.compile(&comp).is_err());
    }
}
