//! Offline stand-in for the `anyhow` crate: the build environment has
//! no crate registry, so the workspace carries the small subset of the
//! API it actually uses — a string-backed [`Error`], the [`Result`]
//! alias, the [`anyhow!`]/[`bail!`] macros and the [`Context`] trait.
//! Mirrors the real crate's shape so swapping the dependency line back
//! to crates.io anyhow requires no source changes.

/// String-backed error value. Like the real `anyhow::Error`, it
/// deliberately does NOT implement `std::error::Error` — that is what
/// makes the blanket [`From`] conversion below coherent.
pub struct Error(String);

impl Error {
    /// Construct from anything displayable (the `anyhow!` macro's
    /// entry point).
    pub fn msg<M: std::fmt::Display>(m: M) -> Error {
        Error(m.to_string())
    }

    /// Prefix the error with additional context.
    pub fn context<C: std::fmt::Display>(self, c: C) -> Error {
        Error(format!("{c}: {}", self.0))
    }
}

impl std::fmt::Display for Error {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(&self.0)
    }
}

impl std::fmt::Debug for Error {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(&self.0)
    }
}

impl<E: std::error::Error + Send + Sync + 'static> From<E> for Error {
    fn from(e: E) -> Error {
        Error(e.to_string())
    }
}

/// `anyhow`-style result alias with a defaulted error type.
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Build an [`Error`] from a format string.
#[macro_export]
macro_rules! anyhow {
    ($($arg:tt)*) => {
        $crate::Error::msg(format!($($arg)*))
    };
}

/// Return early with a formatted [`Error`].
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return Err($crate::anyhow!($($arg)*))
    };
}

/// Attach context to failible values (`Result` of any displayable
/// error, or `Option`).
pub trait Context<T> {
    fn context<C: std::fmt::Display>(self, c: C) -> Result<T>;
    fn with_context<C: std::fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T>;
}

impl<T, E: std::fmt::Display> Context<T> for std::result::Result<T, E> {
    fn context<C: std::fmt::Display>(self, c: C) -> Result<T> {
        self.map_err(|e| Error(format!("{c}: {e}")))
    }
    fn with_context<C: std::fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.map_err(|e| Error(format!("{}: {e}", f())))
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: std::fmt::Display>(self, c: C) -> Result<T> {
        self.ok_or_else(|| Error(c.to_string()))
    }
    fn with_context<C: std::fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.ok_or_else(|| Error(f().to_string()))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> Result<usize> {
        let n: usize = s.parse().context("bad number")?;
        if n == 0 {
            bail!("zero is not allowed: '{s}'");
        }
        Ok(n)
    }

    #[test]
    fn macro_and_question_mark_paths() {
        assert_eq!(parse("7").unwrap(), 7);
        let e = parse("x").unwrap_err().to_string();
        assert!(e.starts_with("bad number:"), "{e}");
        let z = parse("0").unwrap_err().to_string();
        assert!(z.contains("zero is not allowed"), "{z}");
    }

    #[test]
    fn option_and_with_context() {
        let none: Option<usize> = None;
        let e = none.context("missing").unwrap_err();
        assert_eq!(e.to_string(), "missing");
        let io: std::result::Result<(), std::fmt::Error> = Err(std::fmt::Error);
        let e = io.with_context(|| format!("step {}", 3)).unwrap_err();
        assert!(e.to_string().starts_with("step 3:"), "{e}");
    }

    #[test]
    fn from_std_error_via_question_mark() {
        fn io_fail() -> Result<String> {
            let s = std::fs::read_to_string("/definitely/not/a/file")?;
            Ok(s)
        }
        assert!(io_fail().is_err());
        let e = Error::msg("base").context("outer");
        assert_eq!(format!("{e:?}"), "outer: base");
    }
}
